"""Async fleet benchmark: participation rounds + multi-host scaling.

Like ``serving_sharded``, the measurement needs a multi-device jax
runtime (4 fake hosts), so ``fleet_async_bench`` re-execs THIS module
as a child under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
and parses the row the child prints.  Only the child imports jax.

Measured (N=16 devices over the two bench families, 3 rounds):

  sync              : one-shot ``train_fleet`` over the same total steps
  async_ideal       : async rounds, dropout=0, full participation —
                      asserted bit-for-bit equal to sync, and
                      ``stale_merge_overhead`` = t_async / t_sync is the
                      price of round-slicing the scan (gated LOWER)
  async_stragglers  : dropout=0.25 + mild latency under a stale-merge
                      deadline — participation_rate (gated HIGHER),
                      staleness p95, rounds/s
  devices_per_host_scaling : host-resident fleet state bytes at 1 host
                      / at 4 hosts (``sharding.host_resident_bytes``) —
                      the multi-host capacity claim, gated HIGHER with a
                      >= 1.8x floor asserted in-bench

Merges the row into BENCH_fleet.json under "fleet_async" (read-modify-
write — the fleet_scaling columns are preserved).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_MARK = "BENCH_FLEET_ASYNC_JSON:"
_N_HOSTS = 4
_MIN_HOST_SCALING = 1.8


def fleet_async_bench(log=print):
    """Parent entry: run the measurement in a fresh 4-host child and
    merge its row into BENCH_fleet.json."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={_N_HOSTS}"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run([sys.executable, "-m", "benchmarks.fleet_async"],
                          capture_output=True, text=True, env=env, cwd=root,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"fleet async child failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    row = None
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            row = json.loads(line[len(_MARK):])
        elif line.strip():
            log(f"  {line}")
    if row is None:
        raise RuntimeError(f"child emitted no row:\n{proc.stdout}")

    path = os.path.join(root, "BENCH_fleet.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["fleet_async"] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  fleet_async: ideal overhead "
        f"{row['modes']['async_ideal']['stale_merge_overhead']}x, "
        f"straggler participation "
        f"{row['modes']['async_stragglers']['participation_rate']}, "
        f"host scaling {row['devices_per_host_scaling']}x")
    return row


def _child_main(n_devices: int = 16, rounds: int = 3,
                steps_per_round: int = 4, seed: int = 0):
    import dataclasses
    import time

    import jax
    import numpy as np

    from benchmarks.common import SEQ, device_families, sim_cfg
    from repro.data.federated import FederatedCorpus
    from repro.federated import (STRAGGLER_PROFILES, AsyncFleetConfig,
                                 build_fleet, train_fleet,
                                 train_fleet_async)
    from repro.federated.device import (_device_init, _pad_lanes,
                                        _shard_bucket, _stack_trees,
                                        fleet_buckets)
    from repro.launch.mesh import make_fleet_mesh
    from repro.sharding import host_resident_bytes

    assert len(jax.devices()) == _N_HOSTS, jax.devices()
    sim = sim_cfg(n_devices, seed)
    total = rounds * steps_per_round
    batch = sim.device_batch
    corpus = FederatedCorpus.build(seed=seed, n_devices=n_devices,
                                   n_domains=sim.n_domains, vocab=sim.vocab,
                                   alpha=sim.alpha_noniid)
    fleet = build_fleet(sim, corpus, device_families())
    kw = dict(batch=batch, seq_len=SEQ, seed=seed)

    def best_of(fn, n=2):
        """(best wall_s, last result) — best-of-n damps scheduler noise,
        the gated overhead ratio needs stable numerators."""
        best, out = float("inf"), None
        for _ in range(n):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    # -- sync baseline (same total steps) ------------------------------
    train_fleet(fleet, corpus, steps=total, **kw)            # warmup
    t_sync, sync_ups = best_of(
        lambda: train_fleet(fleet, corpus, steps=total, **kw))

    # -- async, ideal fleet: must reproduce sync bit-for-bit -----------
    ideal = AsyncFleetConfig(rounds=rounds, steps_per_round=steps_per_round)
    train_fleet_async(fleet, corpus, ideal, **kw)            # warmup
    t_async, (async_ups, ideal_rep) = best_of(
        lambda: train_fleet_async(fleet, corpus, ideal, **kw))
    for a, s in zip(async_ups, sync_ups):
        assert a["losses"] == s["losses"]
        for xa, xs in zip(jax.tree.leaves(a["params"]),
                          jax.tree.leaves(s["params"])):
            assert (np.asarray(xa) == np.asarray(xs)).all(), \
                "async ideal fleet diverged from synchronous train_fleet"
    print(f"ideal: {rounds}x{steps_per_round} async rounds == {total}-step "
          f"train_fleet bit-for-bit ({t_async:.2f}s vs {t_sync:.2f}s sync)")

    # -- async with stragglers -----------------------------------------
    strag_fleet = build_fleet(sim, corpus, device_families(),
                              traffic=dataclasses.replace(
                                  STRAGGLER_PROFILES["mild"],
                                  dropout_p=0.25))
    strag = AsyncFleetConfig(rounds=rounds, steps_per_round=steps_per_round,
                             deadline_s=1.0, deadline_policy="stale")
    train_fleet_async(strag_fleet, corpus, strag, **kw)      # warmup
    t0 = time.perf_counter()
    _, srep = train_fleet_async(strag_fleet, corpus, strag, **kw)
    t_strag = time.perf_counter() - t0

    # -- multi-host resident-state scaling -----------------------------
    mesh = make_fleet_mesh(_N_HOSTS)
    b1 = b4 = 0
    for cfg, specs in fleet_buckets(fleet).items():
        inits = [_device_init(s, seed, "") for s in specs]
        params = _stack_trees([p for p, _ in inits])
        opt = _stack_trees([o for _, o in inits])
        b1 += host_resident_bytes(params) + host_resident_bytes(opt)
        n_pad = (-len(specs)) % _N_HOSTS
        params, opt = (_pad_lanes(t, n_pad) for t in (params, opt))
        params, opt = _shard_bucket(mesh, params, opt)
        b4 += host_resident_bytes(params) + host_resident_bytes(opt)
    scaling = round(b1 / max(b4, 1), 2)
    assert scaling >= _MIN_HOST_SCALING, \
        (f"devices_per_host_scaling {scaling} < {_MIN_HOST_SCALING}: "
         f"sharding the stacked fleet over {_N_HOSTS} hosts kept too much "
         f"state resident per host")
    print(f"host scaling: {b1} B resident at 1 host vs {b4} B at "
          f"{_N_HOSTS} hosts ({scaling}x)")

    row = {
        "n_devices": n_devices,
        "rounds": rounds,
        "steps_per_round": steps_per_round,
        "n_hosts": _N_HOSTS,
        "modes": {
            "sync": {"wall_s": round(t_sync, 3)},
            "async_ideal": {
                "wall_s": round(t_async, 3),
                "rounds_per_s": round(rounds / max(t_async, 1e-9), 3),
                "stale_merge_overhead": round(t_async / max(t_sync, 1e-9),
                                              2),
                "participation_rate": ideal_rep["participation_rate"],
                "bitwise_equals_sync": True,
            },
            "async_stragglers": {
                "wall_s": round(t_strag, 3),
                "rounds_per_s": round(rounds / max(t_strag, 1e-9), 3),
                "participation_rate": srep["participation_rate"],
                "staleness_p95": srep["staleness_p95"],
                "stale_merged": sum(r["stale_merged"]
                                    for r in srep["rounds"]),
                "lost_reports": srep["lost_reports"],
                "comm_bytes_global": srep["comm_bytes_global"],
            },
        },
        "devices_per_host_scaling": scaling,
        "note": ("stale_merge_overhead = async-ideal / sync wall clock at "
                 "equal total steps (round-slicing the compiled scan); "
                 "devices_per_host_scaling = host-resident fleet state at "
                 "1 host / at 4 hosts (fleet_specs sharding), both "
                 "machine-independent and regression-gated."),
    }
    print(_MARK + json.dumps(row))


if __name__ == "__main__":
    _child_main()
