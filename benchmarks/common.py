"""Shared benchmark scaffolding.

The paper's experiments run 14-16B MoEs on GPU clusters; this container
is one CPU core, so benchmarks run the SAME pipeline at reduced scale
(tiny configs, small N) — the *claims* being validated are relative
(DeepFusion vs baselines on identical data), see EXPERIMENTS.md.
Results are cached under experiments/bench/ so table1/table2/fig9 share
one underlying run per system size.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.federated.server import ServerConfig
from repro.federated.simulation import SimulationConfig
from repro.models.config import ModelConfig

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "bench")

VOCAB = 256
SEQ = 48


def device_families():
    """Two heterogeneous on-device LLM families (gpt2-ish / llama-ish)."""
    small = dict(vocab_size=VOCAB, dtype="float32", remat=False,
                 attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32)
    a = ModelConfig(name="gpt2-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=4, head_dim=16, d_ff=128,
                    norm_type="layernorm", act="gelu", mlp_gated=False,
                    pos_embedding="sinusoidal", **small).validate()
    b = ModelConfig(name="llama-tiny", n_layers=3, d_model=96, n_heads=4,
                    n_kv_heads=2, head_dim=24, d_ff=192, **small).validate()
    return [a, b]


def global_moe_cfg():
    small = dict(vocab_size=VOCAB, dtype="float32", remat=False,
                 attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32)
    return ModelConfig(name="qwen-moe-tiny", arch_type="moe", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                       d_ff=128, n_experts=4, top_k=2, moe_d_ff=128,
                       n_shared_experts=1, **small).validate()


def sim_cfg(n_devices: int, seed: int = 0) -> SimulationConfig:
    return SimulationConfig(n_devices=n_devices, n_domains=4, vocab=VOCAB,
                            seq_len=SEQ, device_steps=30, device_batch=8,
                            seed=seed)


def server_cfg(seed: int = 0) -> ServerConfig:
    return ServerConfig(moe_cfg=global_moe_cfg(), distill_steps=40,
                        distill_batch=8, tune_steps=40, tune_batch=8,
                        seq_len=SEQ, n_stages=2, p_q=32, vaa_dim=64,
                        seed=seed)


def cache_path(name: str) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, name + ".json")


def cached(name: str):
    p = cache_path(name)
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def store(name: str, obj) -> None:
    with open(cache_path(name), "w") as f:
        json.dump(obj, f, indent=1)


def timed(fn, *args, repeats: int = 3, **kw):
    """us per call after a warmup call (jit-compiled paths)."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6, out
