"""Serving benchmark: python-loop vs scanned decode vs continuous batching.

Drives the SAME Poisson-arrival, mixed prompt/gen-length traffic through
three serving paths (greedy decoding, identical outputs):

  python_loop : per-request B=1, one jit dispatch per generated token —
                the seed repo's serving path.
  scanned     : per-request B=1, the whole decode loop as ONE
                ``lax.scan`` dispatch (``models.model.generate``).
  continuous  : the slot-based ``ServeEngine`` — scanned segments over a
                fixed-capacity batch, finished slots refilled from the
                queue between segments.

Each mode runs once untimed (compile warmup; the prefill jit is the
engine's own, so the three modes share its compile cache), then once
timed.  Writes BENCH_serve.json at the repo root.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, ServeEngine
from repro.serve.engine import _prefill_fn

PROMPT_LENS = (8, 16, 24)
GEN_LENS = (6, 10, 14)
MEAN_GAP_S = 0.002


@functools.lru_cache(maxsize=None)
def _step_fn(cfg):
    return jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))


def _traffic(cfg, n: int, seed: int):
    rng = np.random.default_rng(seed)
    lengths = [(int(rng.choice(PROMPT_LENS)), int(rng.choice(GEN_LENS)))
               for _ in range(n)]
    gaps = rng.exponential(MEAN_GAP_S, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]  # first request arrives at t=0
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, p)),
                                      jnp.int32)}
               for p, _ in lengths]
    return batches, lengths, arrivals


def _wait(arrival: float, t0: float) -> None:
    dt = arrival - (time.perf_counter() - t0)
    if dt > 0:
        time.sleep(dt)


def _serve_python_loop(params, cfg, batches, lengths, arrivals, max_len, t0):
    pf, step = _prefill_fn(cfg, None), _step_fn(cfg)
    outs = {}
    for uid, (b, (p, g)) in enumerate(zip(batches, lengths)):
        _wait(arrivals[uid], t0)
        logits, pc = pf(params, b)
        cache = M.prefill_into_cache(
            cfg, M.init_decode_cache(cfg, 1, max_len), pc)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks = [int(tok[0, 0])]
        pos0 = M.decode_pos0(cfg, p)
        for i in range(g - 1):
            logits, cache = step(params, cache, tok,
                                 jnp.full((1,), pos0 + i, jnp.int32))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(int(tok[0, 0]))
        outs[uid] = toks
    return outs, {}


def _serve_scanned(params, cfg, batches, lengths, arrivals, max_len, t0):
    pf = _prefill_fn(cfg, None)
    outs = {}
    for uid, (b, (p, g)) in enumerate(zip(batches, lengths)):
        _wait(arrivals[uid], t0)
        logits, pc = pf(params, b)
        cache = M.prefill_into_cache(
            cfg, M.init_decode_cache(cfg, 1, max_len), pc)
        e0 = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [int(e0[0])]
        if g > 1:
            res = M.generate(params, cfg, cache, e0,
                             jnp.asarray([M.decode_pos0(cfg, p)]),
                             steps=g - 1)
            toks += np.asarray(res["tokens"])[0][
                np.asarray(res["valid"])[0]].tolist()
        outs[uid] = toks
    return outs, {}


def _drive_engine(eng, batches, lengths, arrivals, t0):
    """One traffic replay through a LONG-LIVED engine (uids reused via
    ``pop_completions`` — the engine's per-length compile caches stay
    warm across replays, like a production server's).  Per-replay stats
    are deltas against the engine's cumulative counters."""
    # the peaks are max-tracked, not summed: rebase them so this replay
    # reports ITS concurrency, not the warmup replay's
    eng.stats["peak_live_requests"] = 0
    if "peak_live_blocks" in eng.stats:
        eng.stats["peak_live_blocks"] = 0
    base = dict(eng.stats)
    i, n = 0, len(batches)
    while i < n or not eng.idle:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            eng.submit(batches[i], max_new=lengths[i][1], uid=i)
            i += 1
        if eng.idle:
            _wait(arrivals[i], t0)
            continue
        eng.step()
    outs = {uid: c.tokens.tolist()
            for uid, c in eng.pop_completions().items()}
    seg = eng.stats["segments"] - base["segments"]
    live = eng.stats["live_slot_steps"] - base["live_slot_steps"]
    steps = eng.stats["slot_steps"] - base["slot_steps"]
    extra = {"segments": seg, "slot_util": round(live / max(steps, 1), 3),
             "peak_live_requests": eng.stats["peak_live_requests"]}
    if "shared_blocks" in eng.stats:
        extra.update(
            shared_blocks=eng.stats["shared_blocks"] - base["shared_blocks"],
            peak_live_blocks=eng.stats["peak_live_blocks"])
    return outs, extra


def _serve_engine_mode(params, cfg, batches, lengths, arrivals, max_len, t0,
                       *, engine):
    del params, cfg, max_len  # resident in the long-lived engine
    return _drive_engine(engine, batches, lengths, arrivals, t0)


def _timed_replays(fn, params, cfg, batches, lengths, arrivals, max_len,
                   total_tokens, name, repeats: int):
    """Warmup once, then ``repeats`` timed replays keeping the BEST wall
    time — sub-second serving runs on shared CI hosts are scheduler-noisy
    and the regression gate needs a stable number."""
    fn(params, cfg, batches, lengths, arrivals, max_len,
       time.perf_counter())  # warmup: compiles every shape variant
    best, outs, extra = None, None, None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        outs, extra = fn(params, cfg, batches, lengths, arrivals, max_len, t0)
        wall = time.perf_counter() - t0
        n_tok = sum(len(v) for v in outs.values())
        assert n_tok == total_tokens, (name, n_tok, total_tokens)
        best = wall if best is None else min(best, wall)
    return best, outs, extra


def serving_bench(n_requests: int = 10, *, n_slots: int = 4, seg_len: int = 8,
                  seed: int = 0, arch: str = "qwen2-moe-a2.7b", repeats: int = 3,
                  log=print):
    """Runs the three serving modes on identical traffic; returns + writes
    the BENCH_serve.json payload."""
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batches, lengths, arrivals = _traffic(cfg, n_requests, seed)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)

    modes = {
        "python_loop": _serve_python_loop,
        "scanned": _serve_scanned,
        "continuous": functools.partial(
            _serve_engine_mode,
            engine=ServeEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                               seg_len=seg_len)),
    }
    results, outputs = {}, {}
    for name, fn in modes.items():
        wall, outs, extra = _timed_replays(
            fn, params, cfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2),
                         "tokens": n_tok, **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s "
            f"({results[name]['tok_s']} tok/s)")

    match = all(outputs[m] == outputs["python_loop"] for m in outputs)
    # greedy decoding: all three paths MUST emit identical tokens —
    # speedups for a diverging decode path would be meaningless
    assert match, "serving modes diverged (scanned/continuous vs loop)"
    payload = {
        "arch": cfg.name,
        "traffic": {"n_requests": n_requests, "prompt_lens": PROMPT_LENS,
                    "gen_lens": GEN_LENS, "mean_gap_s": MEAN_GAP_S,
                    "seed": seed, "total_tokens": total_tokens},
        "engine": {"n_slots": n_slots, "seg_len": seg_len,
                   "max_len": max_len},
        "modes": results,
        "outputs_match_across_modes": match,
        "speedup_scan_vs_loop": round(
            results["scanned"]["tok_s"] / results["python_loop"]["tok_s"], 2),
        "speedup_cb_vs_loop": round(
            results["continuous"]["tok_s"] / results["python_loop"]["tok_s"],
            2),
    }
    out = _bench_path()
    if os.path.exists(out):  # keep the paged/bucketed rows across reruns
        with open(out) as f:
            prev = json.load(f)
        for key in ("paged", "bucketed", "sharded", "speculative",
                    "quantized"):
            if key in prev:
                payload[key] = prev[key]
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  continuous batching {payload['speedup_cb_vs_loop']}x vs "
        f"python loop (outputs match: {match})")
    return payload


def _bench_path():
    return os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")


def _preamble_traffic(cfg, n: int, seed: int, *, preamble_len: int,
                      suffix_len: int):
    """Phase-II-style traffic: every request carries the same task
    preamble plus a per-request suffix (one fixed prompt length, so the
    shared-prefix blocks are bit-exact reuses of one prefill
    executable), with mixed Poisson-arrival generation lengths."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, (1, preamble_len))
    batches, lengths = [], []
    for _ in range(n):
        sfx = rng.integers(0, cfg.vocab_size, (1, suffix_len))
        batches.append({"tokens": jnp.asarray(
            np.concatenate([pre, sfx], axis=1), jnp.int32)})
        lengths.append((preamble_len + suffix_len, int(rng.choice(GEN_LENS))))
    gaps = rng.exponential(MEAN_GAP_S, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]
    return batches, lengths, arrivals


def serving_paged_bench(n_requests: int = 12, *, n_slots: int = 4,
                        seg_len: int = 4, block_len: int = 8, seed: int = 0,
                        arch: str = "qwen2-moe-a2.7b", repeats: int = 3,
                        log=print):
    """Equal-cache-bytes capacity comparison: contiguous slots vs the
    block-paged engine.

    The contiguous engine owns ``n_slots * max_len`` rows no matter how
    short requests run; the paged engine gets a pool of AT MOST the same
    bytes (slot-resident leaves included) but twice the slots, and the
    shared task preamble is pooled once.  Asserts identical greedy
    outputs and a peak concurrent-request count above what
    ``n_slots * max_len`` contiguous memory permits, then appends the
    row to BENCH_serve.json under "paged"."""
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batches, lengths, arrivals = _preamble_traffic(
        cfg, n_requests, seed, preamble_len=2 * block_len,
        suffix_len=block_len)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)

    n_slots_paged = 2 * n_slots
    contig_bytes = M.cache_nbytes(cfg, n_slots, max_len)
    base = M.paged_cache_nbytes(cfg, n_slots_paged, 2, block_len)
    block_bytes = M.paged_cache_nbytes(cfg, n_slots_paged, 3,
                                       block_len) - base
    slot_bytes = M.paged_cache_nbytes(cfg, n_slots_paged + 1, 2,
                                      block_len) - base
    n_blocks = int((contig_bytes - n_slots_paged * slot_bytes) // block_bytes)
    paged_bytes = M.paged_cache_nbytes(cfg, n_slots_paged, n_blocks,
                                       block_len)
    assert paged_bytes <= contig_bytes, (paged_bytes, contig_bytes)

    modes = {
        "continuous": functools.partial(
            _serve_engine_mode,
            engine=ServeEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                               seg_len=seg_len)),
        "paged": functools.partial(
            _serve_engine_mode,
            engine=PagedServeEngine(params, cfg, n_slots=n_slots_paged,
                                    max_len=max_len, seg_len=seg_len,
                                    block_len=block_len,
                                    n_blocks=n_blocks)),
    }
    results, outputs = {}, {}
    for name, fn in modes.items():
        wall, outs, extra = _timed_replays(
            fn, params, cfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2), **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s, peak "
            f"{extra['peak_live_requests']} concurrent")
    # greedy + slot independence: both engines must emit identical tokens
    assert outputs["paged"] == outputs["continuous"], \
        "paged engine diverged from contiguous"
    # the capacity claim: more live requests than n_slots * max_len
    # contiguous bytes can hold, at equal (or fewer) cache bytes
    assert results["paged"]["peak_live_requests"] > n_slots, results

    row = {
        "concurrency_gain": round(
            results["paged"]["peak_live_requests"]
            / results["continuous"]["peak_live_requests"], 2),
        "arch": cfg.name,
        "traffic": {"n_requests": n_requests,
                    "preamble_len": 2 * block_len, "suffix_len": block_len,
                    "gen_lens": GEN_LENS, "seed": seed,
                    "total_tokens": total_tokens},
        "contiguous": {"n_slots": n_slots, "max_len": max_len,
                       "cache_bytes": contig_bytes,
                       **results["continuous"]},
        "paged_engine": {"n_slots": n_slots_paged, "block_len": block_len,
                         "n_blocks": n_blocks, "cache_bytes": paged_bytes,
                         **results["paged"]},
        "outputs_match": True,
    }
    path = _bench_path()
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["paged"] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  paged: {row['paged_engine']['peak_live_requests']} concurrent "
        f"requests vs {n_slots} contiguous slots at "
        f"{paged_bytes}/{contig_bytes} cache bytes "
        f"({row['paged_engine']['shared_blocks']} prefix-shared blocks)")
    return row


def serving_quantized_bench(n_requests: int = 12, *, n_slots: int = 4,
                            seg_len: int = 4, block_len: int = 8,
                            kv_dtype: str = "int8", seed: int = 0,
                            arch: str = "qwen2-moe-a2.7b",
                            train_steps: int = 150, period: int = 16,
                            repeats: int = 3, log=print):
    """Equal-cache-bytes capacity comparison: fp32 paged engine vs the
    quantized (int8 KV + per-position scales) paged engine reading
    through the fused-dequant Pallas kernel.

    The fp32 engine gets its worst-case pool (every slot can hold
    ``max_len`` tokens); the quantized engine gets a pool of AT MOST
    the same bytes — scale leaves and slot-resident (unquantized)
    leaves included — but ``3 * n_slots`` slots, because int8 rows +
    f32 scales cost ~28% of fp32 rows so ~3.5x the tokens fit in the
    byte budget.  The model is briefly trained on periodic data and
    the traffic drawn from the same process, so greedy logits carry
    real margins — at random init a 256-token vocab is all near-ties
    and ANY cache rounding flips some of them, which would make the
    equality gate measure tie-breaking luck, not the quantizer.
    Asserts identical greedy outputs (int8 KV shifts logits ~2e-2 on
    this model — well inside a trained margin; fp8's ~1e-1 is not and
    is excluded from the gate), a >= 1.5x peak-concurrency gain, and
    that the quantized engine actually read through the Pallas kernel
    path.  Appends the row to BENCH_serve.json under "quantized"."""
    from repro.models import quant
    from repro.models.layers import paged_read_path

    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    # the quantized engine reads through the fused-dequant kernel
    # (interpret mode on CPU); the fp32 baseline keeps the gather read
    # so the bench compares the two SHIPPING configurations
    cfg_q = cfg.replace(use_pallas=True)
    assert paged_read_path(cfg_q, 1) == "pallas", \
        "quantized engine must serve through the Pallas kernel"
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    params = _train_briefly(params, cfg, steps=train_steps, period=period,
                            depth=0, seed=seed, log=log)
    batches, lengths, arrivals = _periodic_traffic(
        cfg, n_requests, seed, period=period, gen_lens=GEN_LENS)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)

    max_blocks = -(-max_len // block_len)
    n_blocks_fp = 1 + n_slots * max_blocks  # worst-case fp32 pool
    fp_bytes = M.paged_cache_nbytes(cfg, n_slots, n_blocks_fp, block_len)
    # size the quantized pool to the fp32 byte budget by finite
    # differences of the policy-aware estimator (block pools and slot
    # leaves both scale linearly, so two probes recover the increments)
    pol = quant.CachePolicy(kv_dtype)
    n_slots_q = 3 * n_slots
    base = M.paged_cache_nbytes(cfg_q, n_slots_q, 2, block_len, policy=pol)
    block_bytes = M.paged_cache_nbytes(cfg_q, n_slots_q, 3, block_len,
                                       policy=pol) - base
    slot_bytes = M.paged_cache_nbytes(cfg_q, n_slots_q + 1, 2, block_len,
                                      policy=pol) - base
    n_blocks_q = int((fp_bytes - n_slots_q * slot_bytes) // block_bytes)
    q_bytes = M.paged_cache_nbytes(cfg_q, n_slots_q, n_blocks_q, block_len,
                                   policy=pol)
    assert q_bytes <= fp_bytes, (q_bytes, fp_bytes)

    modes = {
        "paged_fp32": functools.partial(
            _serve_engine_mode,
            engine=PagedServeEngine(params, cfg, n_slots=n_slots,
                                    max_len=max_len, seg_len=seg_len,
                                    block_len=block_len,
                                    n_blocks=n_blocks_fp)),
        "paged_quantized": functools.partial(
            _serve_engine_mode,
            engine=PagedServeEngine(params, cfg_q, n_slots=n_slots_q,
                                    max_len=max_len, seg_len=seg_len,
                                    block_len=block_len,
                                    n_blocks=n_blocks_q,
                                    kv_dtype=kv_dtype)),
    }
    results, outputs = {}, {}
    for name, fn in modes.items():
        wall, outs, extra = _timed_replays(
            fn, params, cfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2), **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s, peak "
            f"{extra['peak_live_requests']} concurrent")
    # greedy: int8 KV must not flip a single argmax on this traffic —
    # the capacity gain is only claimable for an EQUIVALENT server
    assert outputs["paged_quantized"] == outputs["paged_fp32"], \
        "quantized engine diverged from fp32 paged"
    gain = (results["paged_quantized"]["peak_live_requests"]
            / results["paged_fp32"]["peak_live_requests"])
    # the capacity claim: >= 1.5x concurrent requests in the same bytes
    assert gain >= 1.5, results

    row = {
        "concurrency_gain_quant": round(gain, 2),
        "kv_dtype": kv_dtype,
        "arch": cfg.name,
        "read_path": paged_read_path(cfg_q, 1),
        "traffic": {"n_requests": n_requests, "prompt_lens": PROMPT_LENS,
                    "gen_lens": GEN_LENS, "seed": seed,
                    "total_tokens": total_tokens,
                    "train_steps": train_steps, "period": period},
        "paged_fp32": {"n_slots": n_slots, "block_len": block_len,
                       "n_blocks": n_blocks_fp, "cache_bytes": fp_bytes,
                       **results["paged_fp32"]},
        "paged_quantized": {"n_slots": n_slots_q, "block_len": block_len,
                            "n_blocks": n_blocks_q, "cache_bytes": q_bytes,
                            **results["paged_quantized"]},
        "outputs_match": True,
    }
    path = _bench_path()
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["quantized"] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  quantized: {row['paged_quantized']['peak_live_requests']} "
        f"concurrent requests vs {row['paged_fp32']['peak_live_requests']} "
        f"fp32 at {q_bytes}/{fp_bytes} cache bytes "
        f"({row['concurrency_gain_quant']}x, {kv_dtype} KV, "
        f"{row['read_path']} read)")
    return row


def _train_briefly(params, cfg, *, steps: int, period: int, depth: int,
                   lr: float = 2e-3, seed: int = 0, log=print):
    """A few hundred Adam steps on periodic synthetic sequences.  The
    point is an HONEST speculative-decode benchmark: the MTP head only
    accelerates decode if it actually predicts, and a freshly-initialized
    head accepts ~nothing.  The base loss only supervises MTP depth 1;
    speculative decode CHAINS the head ``depth`` times, so train with
    ``mtp_chain_loss`` too — otherwise acceptance collapses past the
    first draft (out-of-distribution hidden feedback).  ``depth=0``
    skips the chain loss: the quantized-cache bench trains the plain LM
    objective only to sharpen greedy logits (random-init logits at a
    256-token vocab are near-ties that ANY cache rounding can flip)."""
    B, S = 8, 33

    def batch_for(key):
        start = jax.random.randint(key, (B, 1), 0, period)
        toks = (start + jnp.arange(S)[None, :]) % period
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}

    def full_loss(params, batch):
        loss, aux = M.loss_fn(params, cfg, batch)
        if depth:
            loss = loss + cfg.mtp_loss_weight * M.mtp_chain_loss(
                params, cfg, batch, depth=depth)
        return loss, aux

    @jax.jit
    def step(params, m, v, i, key):
        (loss, _), g = jax.value_and_grad(full_loss, has_aux=True)(
            params, batch_for(key))
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.99 * a + 0.01 * b * b, v, g)
        t = i + 1.0
        params = jax.tree.map(
            lambda p, a, b: p - lr * (a / (1 - 0.9 ** t))
            / (jnp.sqrt(b / (1 - 0.99 ** t)) + 1e-8), params, m, v)
        return params, m, v, loss

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    loss = None
    for i, key in enumerate(jax.random.split(jax.random.PRNGKey(seed), steps)):
        params, m, v, loss = step(params, m, v, float(i), key)
    log(f"  trained {steps} steps on period-{period} data "
        f"(final loss {float(loss):.3f})")
    return params


def _periodic_traffic(cfg, n: int, seed: int, *, period: int, gen_lens):
    """Prompts drawn from the same periodic process the model was
    trained on, so greedy decode (and the MTP drafts) continue the
    pattern instead of wandering through untrained token space."""
    rng = np.random.default_rng(seed)
    batches, lengths = [], []
    for _ in range(n):
        p = int(rng.choice(PROMPT_LENS))
        start = int(rng.integers(0, period))
        toks = (start + np.arange(p)) % period
        batches.append({"tokens": jnp.asarray(toks[None, :], jnp.int32)})
        lengths.append((p, int(rng.choice(gen_lens))))
    gaps = rng.exponential(MEAN_GAP_S, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]
    return batches, lengths, arrivals


def serving_speculative_bench(n_requests: int = 12, *, n_slots: int = 4,
                              seg_len: int = 6, n_draft: int = 3,
                              seed: int = 0, arch: str = "deepseek-v3-671b",
                              train_steps: int = 400, period: int = 16,
                              repeats: int = 5, log=print):
    """Self-speculative MTP decode vs plain continuous batching on the
    SAME traffic: the MTP head drafts ``n_draft`` tokens per compiled
    step and the backbone verifies them in one C = n_draft+1 chunk, so a
    step that accepts everything advances 4 tokens for ~one step's
    latency.  The model is briefly trained on periodic data first —
    speculative throughput is meaningless at random init (acceptance
    ~0).  Both engines share every knob (seg_len=6: long enough to
    amortize host work per segment, short enough that a speculative
    segment — up to seg_len*(n_draft+1) emissions per slot — doesn't
    overshoot a finished request into dead steps).  Asserts identical
    greedy outputs and appends the row to BENCH_serve.json under
    "speculative"."""
    # 4 backbone layers, not the reduced default of 2: the draft head is
    # ONE layer chained n_draft times, so at 2 layers drafting costs 1.5
    # backbones and the step economics misrepresent real models (tens of
    # layers per single-layer MTP head).  4 layers already puts the
    # verify step at ~1.2x a plain step.
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256,
                                                      n_layers=4)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    params = _train_briefly(params, cfg, steps=train_steps, period=period,
                            depth=n_draft, seed=seed, log=log)
    # much longer generations than the base bench: prefill and host
    # overhead are identical across the two engines, so short gens dilute
    # the decode speedup the row is meant to gate
    gen_lens = (48, 64, 96)
    batches, lengths, arrivals = _periodic_traffic(
        cfg, n_requests, seed, period=period, gen_lens=gen_lens)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)

    engines = {
        "continuous": ServeEngine(params, cfg, n_slots=n_slots,
                                  max_len=max_len, seg_len=seg_len),
        "speculative": ServeEngine(params, cfg, n_slots=n_slots,
                                   max_len=max_len, seg_len=seg_len,
                                   speculate=n_draft),
    }
    results, outputs = {}, {}
    for name, eng in engines.items():
        fn = functools.partial(_serve_engine_mode, engine=eng)
        wall, outs, extra = _timed_replays(
            fn, params, cfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2),
                         "tokens": n_tok, **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s "
            f"({results[name]['tok_s']} tok/s)")
    # greedy: acceptance is exact argmax prefix matching, so speculative
    # decode must be a pure latency optimization — identical tokens
    match = outputs["speculative"] == outputs["continuous"]
    assert match, "speculative decode diverged from plain decode"
    acc = engines["speculative"].spec_acceptance()
    speedup = round(results["speculative"]["tok_s"]
                    / results["continuous"]["tok_s"], 2)

    row = {
        "arch": cfg.name,
        "n_draft": n_draft,
        "traffic": {"n_requests": n_requests, "prompt_lens": PROMPT_LENS,
                    "gen_lens": gen_lens, "seed": seed,
                    "total_tokens": total_tokens,
                    "train_steps": train_steps, "period": period},
        "engine": {"n_slots": n_slots, "seg_len": seg_len,
                   "max_len": max_len},
        "modes": results,
        "acceptance_rate": round(acc, 3),
        "speedup_spec_vs_cb": speedup,
        "outputs_match_unspeculated": match,
    }
    path = _bench_path()
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["speculative"] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  speculative: {speedup}x vs continuous batching "
        f"(acceptance {acc:.1%}, outputs match: {match})")
    return row


def _open_world_traffic(cfg, n: int, seed: int, *, min_p: int = 5,
                        max_p: int = 28):
    """Open-world traffic: (nearly) every request arrives with a
    DIFFERENT prompt length — the compile-thrash worst case the bucket
    ladder is built for."""
    rng = np.random.default_rng(seed)
    plens = rng.permutation(np.arange(min_p, max_p + 1))[:n]
    if n > len(plens):
        plens = np.concatenate(
            [plens, rng.integers(min_p, max_p + 1, n - len(plens))])
    lengths = [(int(p), int(rng.choice(GEN_LENS))) for p in plens]
    gaps = rng.exponential(MEAN_GAP_S, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, p)),
                                      jnp.int32)}
               for p, _ in lengths]
    return batches, lengths, arrivals


def serving_bucketed_bench(n_requests: int = 16, *, n_slots: int = 4,
                           seg_len: int = 4, chunk_len: int = 8,
                           block_len: int = 8, seed: int = 0,
                           arch: str = "qwen2-moe-a2.7b", repeats: int = 3,
                           log=print):
    """Open-world mixed-length traffic: executables built by the
    unbucketed engine (one prefill + one admit per DISTINCT prompt
    length) vs the bucketed chunked-prefill engines (one admit per
    ladder rung) — O(#distinct lengths) vs O(#buckets).  Asserts
    identical greedy outputs across all three engines and appends the
    row to BENCH_serve.json under "bucketed"."""
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batches, lengths, arrivals = _open_world_traffic(cfg, n_requests, seed)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)
    n_distinct = len({p for p, _ in lengths})

    engines = {
        "unbucketed": ServeEngine(params, cfg, n_slots=n_slots,
                                  max_len=max_len, seg_len=seg_len,
                                  compile_cache_size=2 * n_requests),
        "bucketed": ServeEngine(params, cfg, n_slots=n_slots,
                                max_len=max_len, seg_len=seg_len,
                                chunk_len=chunk_len),
        "bucketed_paged": PagedServeEngine(params, cfg, n_slots=n_slots,
                                           max_len=max_len, seg_len=seg_len,
                                           chunk_len=chunk_len,
                                           block_len=block_len),
    }
    results, outputs = {}, {}
    for name, eng in engines.items():
        fn = functools.partial(_serve_engine_mode, engine=eng)
        wall, outs, extra = _timed_replays(
            fn, params, cfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        # steady state: every replay reuses the warmup's executables, so
        # this is exactly the cold-traffic build count
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2),
                         "compiles": eng.compiles_built,
                         **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s, "
            f"{eng.compiles_built} executables built")
    assert outputs["bucketed"] == outputs["unbucketed"], \
        "bucketed engine diverged from unbucketed"
    assert outputs["bucketed_paged"] == outputs["unbucketed"], \
        "bucketed paged engine diverged from unbucketed"
    # the compile-thrash claim: O(#buckets) vs O(#distinct lengths)
    n_buckets = len(engines["bucketed"].buckets)
    assert results["unbucketed"]["compiles"] == 2 * n_distinct
    assert results["bucketed"]["compiles"] <= n_buckets
    assert results["bucketed_paged"]["compiles"] <= n_buckets

    row = {
        "arch": cfg.name,
        "traffic": {"n_requests": n_requests, "n_distinct_lengths": n_distinct,
                    "gen_lens": GEN_LENS, "seed": seed,
                    "total_tokens": total_tokens},
        "engine": {"n_slots": n_slots, "seg_len": seg_len, "max_len": max_len,
                   "chunk_len": chunk_len,
                   "buckets": list(engines["bucketed"].buckets)},
        "modes": results,
        # deterministic, machine-independent gate metric: how many times
        # fewer executables the bucketed engine builds
        "compile_reduction_ratio": round(
            results["unbucketed"]["compiles"]
            / max(results["bucketed"]["compiles"], 1), 2),
        "outputs_match": True,
    }
    path = _bench_path()
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["bucketed"] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  bucketed: {results['bucketed']['compiles']} executables for "
        f"{n_distinct} distinct lengths "
        f"(unbucketed built {results['unbucketed']['compiles']})")
    return row
