"""Serving benchmark: python-loop vs scanned decode vs continuous batching.

Drives the SAME Poisson-arrival, mixed prompt/gen-length traffic through
three serving paths (greedy decoding, identical outputs):

  python_loop : per-request B=1, one jit dispatch per generated token —
                the seed repo's serving path.
  scanned     : per-request B=1, the whole decode loop as ONE
                ``lax.scan`` dispatch (``models.model.generate``).
  continuous  : the slot-based ``ServeEngine`` — scanned segments over a
                fixed-capacity batch, finished slots refilled from the
                queue between segments.

Each mode runs once untimed (compile warmup; the prefill jit is the
engine's own, so the three modes share its compile cache), then once
timed.  Writes BENCH_serve.json at the repo root.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, ServeEngine
from repro.serve.engine import _prefill_fn

PROMPT_LENS = (8, 16, 24)
GEN_LENS = (6, 10, 14)
MEAN_GAP_S = 0.002


@functools.lru_cache(maxsize=None)
def _step_fn(cfg):
    return jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))


def _traffic(cfg, n: int, seed: int):
    rng = np.random.default_rng(seed)
    lengths = [(int(rng.choice(PROMPT_LENS)), int(rng.choice(GEN_LENS)))
               for _ in range(n)]
    gaps = rng.exponential(MEAN_GAP_S, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]  # first request arrives at t=0
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, p)),
                                      jnp.int32)}
               for p, _ in lengths]
    return batches, lengths, arrivals


def _wait(arrival: float, t0: float) -> None:
    dt = arrival - (time.perf_counter() - t0)
    if dt > 0:
        time.sleep(dt)


def _serve_python_loop(params, cfg, batches, lengths, arrivals, max_len, t0):
    pf, step = _prefill_fn(cfg, None), _step_fn(cfg)
    outs = {}
    for uid, (b, (p, g)) in enumerate(zip(batches, lengths)):
        _wait(arrivals[uid], t0)
        logits, pc = pf(params, b)
        cache = M.prefill_into_cache(
            cfg, M.init_decode_cache(cfg, 1, max_len), pc)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks = [int(tok[0, 0])]
        pos0 = M.decode_pos0(cfg, p)
        for i in range(g - 1):
            logits, cache = step(params, cache, tok,
                                 jnp.full((1,), pos0 + i, jnp.int32))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(int(tok[0, 0]))
        outs[uid] = toks
    return outs, {}


def _serve_scanned(params, cfg, batches, lengths, arrivals, max_len, t0):
    pf = _prefill_fn(cfg, None)
    outs = {}
    for uid, (b, (p, g)) in enumerate(zip(batches, lengths)):
        _wait(arrivals[uid], t0)
        logits, pc = pf(params, b)
        cache = M.prefill_into_cache(
            cfg, M.init_decode_cache(cfg, 1, max_len), pc)
        e0 = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [int(e0[0])]
        if g > 1:
            res = M.generate(params, cfg, cache, e0,
                             jnp.asarray([M.decode_pos0(cfg, p)]),
                             steps=g - 1)
            toks += np.asarray(res["tokens"])[0][
                np.asarray(res["valid"])[0]].tolist()
        outs[uid] = toks
    return outs, {}


def _drive_engine(eng, batches, lengths, arrivals, t0):
    """One traffic replay through a LONG-LIVED engine (uids reused via
    ``pop_completions`` — the engine's per-length compile caches stay
    warm across replays, like a production server's).  Per-replay stats
    are deltas against the engine's cumulative counters."""
    # the peaks are max-tracked, not summed: rebase them so this replay
    # reports ITS concurrency, not the warmup replay's
    eng.stats["peak_live_requests"] = 0
    if "peak_live_blocks" in eng.stats:
        eng.stats["peak_live_blocks"] = 0
    base = dict(eng.stats)
    i, n = 0, len(batches)
    while i < n or not eng.idle:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            eng.submit(batches[i], max_new=lengths[i][1], uid=i)
            i += 1
        if eng.idle:
            _wait(arrivals[i], t0)
            continue
        eng.step()
    outs = {uid: c.tokens.tolist()
            for uid, c in eng.pop_completions().items()}
    seg = eng.stats["segments"] - base["segments"]
    live = eng.stats["live_slot_steps"] - base["live_slot_steps"]
    steps = eng.stats["slot_steps"] - base["slot_steps"]
    extra = {"segments": seg, "slot_util": round(live / max(steps, 1), 3),
             "peak_live_requests": eng.stats["peak_live_requests"]}
    if "shared_blocks" in eng.stats:
        extra.update(
            shared_blocks=eng.stats["shared_blocks"] - base["shared_blocks"],
            peak_live_blocks=eng.stats["peak_live_blocks"])
    return outs, extra


def _serve_engine_mode(params, cfg, batches, lengths, arrivals, max_len, t0,
                       *, engine):
    del params, cfg, max_len  # resident in the long-lived engine
    return _drive_engine(engine, batches, lengths, arrivals, t0)


def _timed_replays(fn, params, cfg, batches, lengths, arrivals, max_len,
                   total_tokens, name, repeats: int):
    """Warmup once, then ``repeats`` timed replays keeping the BEST wall
    time — sub-second serving runs on shared CI hosts are scheduler-noisy
    and the regression gate needs a stable number."""
    fn(params, cfg, batches, lengths, arrivals, max_len,
       time.perf_counter())  # warmup: compiles every shape variant
    best, outs, extra = None, None, None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        outs, extra = fn(params, cfg, batches, lengths, arrivals, max_len, t0)
        wall = time.perf_counter() - t0
        n_tok = sum(len(v) for v in outs.values())
        assert n_tok == total_tokens, (name, n_tok, total_tokens)
        best = wall if best is None else min(best, wall)
    return best, outs, extra


def serving_bench(n_requests: int = 10, *, n_slots: int = 4, seg_len: int = 8,
                  seed: int = 0, arch: str = "qwen2-moe-a2.7b", repeats: int = 3,
                  log=print):
    """Runs the three serving modes on identical traffic; returns + writes
    the BENCH_serve.json payload."""
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batches, lengths, arrivals = _traffic(cfg, n_requests, seed)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)

    modes = {
        "python_loop": _serve_python_loop,
        "scanned": _serve_scanned,
        "continuous": functools.partial(
            _serve_engine_mode,
            engine=ServeEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                               seg_len=seg_len)),
    }
    results, outputs = {}, {}
    for name, fn in modes.items():
        wall, outs, extra = _timed_replays(
            fn, params, cfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2),
                         "tokens": n_tok, **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s "
            f"({results[name]['tok_s']} tok/s)")

    match = all(outputs[m] == outputs["python_loop"] for m in outputs)
    # greedy decoding: all three paths MUST emit identical tokens —
    # speedups for a diverging decode path would be meaningless
    assert match, "serving modes diverged (scanned/continuous vs loop)"
    payload = {
        "arch": cfg.name,
        "traffic": {"n_requests": n_requests, "prompt_lens": PROMPT_LENS,
                    "gen_lens": GEN_LENS, "mean_gap_s": MEAN_GAP_S,
                    "seed": seed, "total_tokens": total_tokens},
        "engine": {"n_slots": n_slots, "seg_len": seg_len,
                   "max_len": max_len},
        "modes": results,
        "outputs_match_across_modes": match,
        "speedup_scan_vs_loop": round(
            results["scanned"]["tok_s"] / results["python_loop"]["tok_s"], 2),
        "speedup_cb_vs_loop": round(
            results["continuous"]["tok_s"] / results["python_loop"]["tok_s"],
            2),
    }
    out = _bench_path()
    if os.path.exists(out):  # keep the paged/bucketed rows across reruns
        with open(out) as f:
            prev = json.load(f)
        for key in ("paged", "bucketed", "sharded"):
            if key in prev:
                payload[key] = prev[key]
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  continuous batching {payload['speedup_cb_vs_loop']}x vs "
        f"python loop (outputs match: {match})")
    return payload


def _bench_path():
    return os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")


def _preamble_traffic(cfg, n: int, seed: int, *, preamble_len: int,
                      suffix_len: int):
    """Phase-II-style traffic: every request carries the same task
    preamble plus a per-request suffix (one fixed prompt length, so the
    shared-prefix blocks are bit-exact reuses of one prefill
    executable), with mixed Poisson-arrival generation lengths."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, (1, preamble_len))
    batches, lengths = [], []
    for _ in range(n):
        sfx = rng.integers(0, cfg.vocab_size, (1, suffix_len))
        batches.append({"tokens": jnp.asarray(
            np.concatenate([pre, sfx], axis=1), jnp.int32)})
        lengths.append((preamble_len + suffix_len, int(rng.choice(GEN_LENS))))
    gaps = rng.exponential(MEAN_GAP_S, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]
    return batches, lengths, arrivals


def serving_paged_bench(n_requests: int = 12, *, n_slots: int = 4,
                        seg_len: int = 4, block_len: int = 8, seed: int = 0,
                        arch: str = "qwen2-moe-a2.7b", repeats: int = 3,
                        log=print):
    """Equal-cache-bytes capacity comparison: contiguous slots vs the
    block-paged engine.

    The contiguous engine owns ``n_slots * max_len`` rows no matter how
    short requests run; the paged engine gets a pool of AT MOST the same
    bytes (slot-resident leaves included) but twice the slots, and the
    shared task preamble is pooled once.  Asserts identical greedy
    outputs and a peak concurrent-request count above what
    ``n_slots * max_len`` contiguous memory permits, then appends the
    row to BENCH_serve.json under "paged"."""
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batches, lengths, arrivals = _preamble_traffic(
        cfg, n_requests, seed, preamble_len=2 * block_len,
        suffix_len=block_len)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)

    n_slots_paged = 2 * n_slots
    contig_bytes = M.cache_nbytes(cfg, n_slots, max_len)
    base = M.paged_cache_nbytes(cfg, n_slots_paged, 2, block_len)
    block_bytes = M.paged_cache_nbytes(cfg, n_slots_paged, 3,
                                       block_len) - base
    slot_bytes = M.paged_cache_nbytes(cfg, n_slots_paged + 1, 2,
                                      block_len) - base
    n_blocks = int((contig_bytes - n_slots_paged * slot_bytes) // block_bytes)
    paged_bytes = M.paged_cache_nbytes(cfg, n_slots_paged, n_blocks,
                                       block_len)
    assert paged_bytes <= contig_bytes, (paged_bytes, contig_bytes)

    modes = {
        "continuous": functools.partial(
            _serve_engine_mode,
            engine=ServeEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                               seg_len=seg_len)),
        "paged": functools.partial(
            _serve_engine_mode,
            engine=PagedServeEngine(params, cfg, n_slots=n_slots_paged,
                                    max_len=max_len, seg_len=seg_len,
                                    block_len=block_len,
                                    n_blocks=n_blocks)),
    }
    results, outputs = {}, {}
    for name, fn in modes.items():
        wall, outs, extra = _timed_replays(
            fn, params, cfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2), **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s, peak "
            f"{extra['peak_live_requests']} concurrent")
    # greedy + slot independence: both engines must emit identical tokens
    assert outputs["paged"] == outputs["continuous"], \
        "paged engine diverged from contiguous"
    # the capacity claim: more live requests than n_slots * max_len
    # contiguous bytes can hold, at equal (or fewer) cache bytes
    assert results["paged"]["peak_live_requests"] > n_slots, results

    row = {
        "concurrency_gain": round(
            results["paged"]["peak_live_requests"]
            / results["continuous"]["peak_live_requests"], 2),
        "arch": cfg.name,
        "traffic": {"n_requests": n_requests,
                    "preamble_len": 2 * block_len, "suffix_len": block_len,
                    "gen_lens": GEN_LENS, "seed": seed,
                    "total_tokens": total_tokens},
        "contiguous": {"n_slots": n_slots, "max_len": max_len,
                       "cache_bytes": contig_bytes,
                       **results["continuous"]},
        "paged_engine": {"n_slots": n_slots_paged, "block_len": block_len,
                         "n_blocks": n_blocks, "cache_bytes": paged_bytes,
                         **results["paged"]},
        "outputs_match": True,
    }
    path = _bench_path()
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["paged"] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  paged: {row['paged_engine']['peak_live_requests']} concurrent "
        f"requests vs {n_slots} contiguous slots at "
        f"{paged_bytes}/{contig_bytes} cache bytes "
        f"({row['paged_engine']['shared_blocks']} prefix-shared blocks)")
    return row


def _open_world_traffic(cfg, n: int, seed: int, *, min_p: int = 5,
                        max_p: int = 28):
    """Open-world traffic: (nearly) every request arrives with a
    DIFFERENT prompt length — the compile-thrash worst case the bucket
    ladder is built for."""
    rng = np.random.default_rng(seed)
    plens = rng.permutation(np.arange(min_p, max_p + 1))[:n]
    if n > len(plens):
        plens = np.concatenate(
            [plens, rng.integers(min_p, max_p + 1, n - len(plens))])
    lengths = [(int(p), int(rng.choice(GEN_LENS))) for p in plens]
    gaps = rng.exponential(MEAN_GAP_S, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, p)),
                                      jnp.int32)}
               for p, _ in lengths]
    return batches, lengths, arrivals


def serving_bucketed_bench(n_requests: int = 16, *, n_slots: int = 4,
                           seg_len: int = 4, chunk_len: int = 8,
                           block_len: int = 8, seed: int = 0,
                           arch: str = "qwen2-moe-a2.7b", repeats: int = 3,
                           log=print):
    """Open-world mixed-length traffic: executables built by the
    unbucketed engine (one prefill + one admit per DISTINCT prompt
    length) vs the bucketed chunked-prefill engines (one admit per
    ladder rung) — O(#distinct lengths) vs O(#buckets).  Asserts
    identical greedy outputs across all three engines and appends the
    row to BENCH_serve.json under "bucketed"."""
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batches, lengths, arrivals = _open_world_traffic(cfg, n_requests, seed)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)
    n_distinct = len({p for p, _ in lengths})

    engines = {
        "unbucketed": ServeEngine(params, cfg, n_slots=n_slots,
                                  max_len=max_len, seg_len=seg_len,
                                  compile_cache_size=2 * n_requests),
        "bucketed": ServeEngine(params, cfg, n_slots=n_slots,
                                max_len=max_len, seg_len=seg_len,
                                chunk_len=chunk_len),
        "bucketed_paged": PagedServeEngine(params, cfg, n_slots=n_slots,
                                           max_len=max_len, seg_len=seg_len,
                                           chunk_len=chunk_len,
                                           block_len=block_len),
    }
    results, outputs = {}, {}
    for name, eng in engines.items():
        fn = functools.partial(_serve_engine_mode, engine=eng)
        wall, outs, extra = _timed_replays(
            fn, params, cfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        # steady state: every replay reuses the warmup's executables, so
        # this is exactly the cold-traffic build count
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2),
                         "compiles": eng.compiles_built,
                         **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s, "
            f"{eng.compiles_built} executables built")
    assert outputs["bucketed"] == outputs["unbucketed"], \
        "bucketed engine diverged from unbucketed"
    assert outputs["bucketed_paged"] == outputs["unbucketed"], \
        "bucketed paged engine diverged from unbucketed"
    # the compile-thrash claim: O(#buckets) vs O(#distinct lengths)
    n_buckets = len(engines["bucketed"].buckets)
    assert results["unbucketed"]["compiles"] == 2 * n_distinct
    assert results["bucketed"]["compiles"] <= n_buckets
    assert results["bucketed_paged"]["compiles"] <= n_buckets

    row = {
        "arch": cfg.name,
        "traffic": {"n_requests": n_requests, "n_distinct_lengths": n_distinct,
                    "gen_lens": GEN_LENS, "seed": seed,
                    "total_tokens": total_tokens},
        "engine": {"n_slots": n_slots, "seg_len": seg_len, "max_len": max_len,
                   "chunk_len": chunk_len,
                   "buckets": list(engines["bucketed"].buckets)},
        "modes": results,
        # deterministic, machine-independent gate metric: how many times
        # fewer executables the bucketed engine builds
        "compile_reduction_ratio": round(
            results["unbucketed"]["compiles"]
            / max(results["bucketed"]["compiles"], 1), 2),
        "outputs_match": True,
    }
    path = _bench_path()
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["bucketed"] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  bucketed: {results['bucketed']['compiles']} executables for "
        f"{n_distinct} distinct lengths "
        f"(unbucketed built {results['unbucketed']['compiles']})")
    return row
