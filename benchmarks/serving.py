"""Serving benchmark: python-loop vs scanned decode vs continuous batching.

Drives the SAME Poisson-arrival, mixed prompt/gen-length traffic through
three serving paths (greedy decoding, identical outputs):

  python_loop : per-request B=1, one jit dispatch per generated token —
                the seed repo's serving path.
  scanned     : per-request B=1, the whole decode loop as ONE
                ``lax.scan`` dispatch (``models.model.generate``).
  continuous  : the slot-based ``ServeEngine`` — scanned segments over a
                fixed-capacity batch, finished slots refilled from the
                queue between segments.

Each mode runs once untimed (compile warmup; the prefill jit is the
engine's own, so the three modes share its compile cache), then once
timed.  Writes BENCH_serve.json at the repo root.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve import ServeEngine
from repro.serve.engine import _prefill_fn

PROMPT_LENS = (8, 16, 24)
GEN_LENS = (6, 10, 14)
MEAN_GAP_S = 0.002


@functools.lru_cache(maxsize=None)
def _step_fn(cfg):
    return jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))


def _traffic(cfg, n: int, seed: int):
    rng = np.random.default_rng(seed)
    lengths = [(int(rng.choice(PROMPT_LENS)), int(rng.choice(GEN_LENS)))
               for _ in range(n)]
    gaps = rng.exponential(MEAN_GAP_S, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]  # first request arrives at t=0
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, p)),
                                      jnp.int32)}
               for p, _ in lengths]
    return batches, lengths, arrivals


def _wait(arrival: float, t0: float) -> None:
    dt = arrival - (time.perf_counter() - t0)
    if dt > 0:
        time.sleep(dt)


def _serve_python_loop(params, cfg, batches, lengths, arrivals, max_len, t0):
    pf, step = _prefill_fn(cfg, None), _step_fn(cfg)
    outs = {}
    for uid, (b, (p, g)) in enumerate(zip(batches, lengths)):
        _wait(arrivals[uid], t0)
        logits, pc = pf(params, b)
        cache = M.prefill_into_cache(
            cfg, M.init_decode_cache(cfg, 1, max_len), pc)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks = [int(tok[0, 0])]
        pos0 = M.decode_pos0(cfg, p)
        for i in range(g - 1):
            logits, cache = step(params, cache, tok,
                                 jnp.full((1,), pos0 + i, jnp.int32))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(int(tok[0, 0]))
        outs[uid] = toks
    return outs, {}


def _serve_scanned(params, cfg, batches, lengths, arrivals, max_len, t0):
    pf = _prefill_fn(cfg, None)
    outs = {}
    for uid, (b, (p, g)) in enumerate(zip(batches, lengths)):
        _wait(arrivals[uid], t0)
        logits, pc = pf(params, b)
        cache = M.prefill_into_cache(
            cfg, M.init_decode_cache(cfg, 1, max_len), pc)
        e0 = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [int(e0[0])]
        if g > 1:
            res = M.generate(params, cfg, cache, e0,
                             jnp.asarray([M.decode_pos0(cfg, p)]),
                             steps=g - 1)
            toks += np.asarray(res["tokens"])[0][
                np.asarray(res["valid"])[0]].tolist()
        outs[uid] = toks
    return outs, {}


def _serve_continuous(params, cfg, batches, lengths, arrivals, max_len, t0,
                      *, n_slots, seg_len):
    eng = ServeEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                      seg_len=seg_len)
    i, n = 0, len(batches)
    while i < n or not eng.idle:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            eng.submit(batches[i], max_new=lengths[i][1])
            i += 1
        if eng.idle:
            _wait(arrivals[i], t0)
            continue
        eng.step()
    outs = {uid: c.tokens.tolist() for uid, c in eng.completions.items()}
    util = eng.stats["live_slot_steps"] / max(eng.stats["slot_steps"], 1)
    return outs, {"segments": eng.stats["segments"],
                  "slot_util": round(util, 3)}


def serving_bench(n_requests: int = 10, *, n_slots: int = 4, seg_len: int = 8,
                  seed: int = 0, arch: str = "qwen2-moe-a2.7b", log=print):
    """Runs the three serving modes on identical traffic; returns + writes
    the BENCH_serve.json payload."""
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batches, lengths, arrivals = _traffic(cfg, n_requests, seed)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)

    modes = {
        "python_loop": _serve_python_loop,
        "scanned": _serve_scanned,
        "continuous": functools.partial(_serve_continuous, n_slots=n_slots,
                                        seg_len=seg_len),
    }
    results, outputs = {}, {}
    for name, fn in modes.items():
        fn(params, cfg, batches, lengths, arrivals, max_len,
           time.perf_counter())  # warmup: compiles every shape variant
        t0 = time.perf_counter()
        outs, extra = fn(params, cfg, batches, lengths, arrivals, max_len, t0)
        wall = time.perf_counter() - t0
        n_tok = sum(len(v) for v in outs.values())
        assert n_tok == total_tokens, (name, n_tok, total_tokens)
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2),
                         "tokens": n_tok, **extra}
        outputs[name] = outs
        log(f"  {name}: {n_tok} tok in {wall:.3f}s "
            f"({results[name]['tok_s']} tok/s)")

    match = all(outputs[m] == outputs["python_loop"] for m in outputs)
    # greedy decoding: all three paths MUST emit identical tokens —
    # speedups for a diverging decode path would be meaningless
    assert match, "serving modes diverged (scanned/continuous vs loop)"
    payload = {
        "arch": cfg.name,
        "traffic": {"n_requests": n_requests, "prompt_lens": PROMPT_LENS,
                    "gen_lens": GEN_LENS, "mean_gap_s": MEAN_GAP_S,
                    "seed": seed, "total_tokens": total_tokens},
        "engine": {"n_slots": n_slots, "seg_len": seg_len,
                   "max_len": max_len},
        "modes": results,
        "outputs_match_across_modes": match,
        "speedup_scan_vs_loop": round(
            results["scanned"]["tok_s"] / results["python_loop"]["tok_s"], 2),
        "speedup_cb_vs_loop": round(
            results["continuous"]["tok_s"] / results["python_loop"]["tok_s"],
            2),
    }
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  continuous batching {payload['speedup_cb_vs_loop']}x vs "
        f"python loop (outputs match: {match})")
    return payload
