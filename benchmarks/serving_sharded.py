"""Sharded serving benchmark: decode-mesh engine vs single device, and
the EP-A2A overlap win.

The measurement needs a multi-device jax runtime, but the bench runner
process has usually initialised jax single-device already (XLA_FLAGS
cannot be applied after backend init) — so ``serving_sharded_bench``
re-execs THIS module as a child with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and parses the
row the child prints.  Only the child imports jax.

Modes (identical Poisson traffic, greedy, token-identical asserted):

  single          : ServeEngine, mesh=None
  sharded         : ServeEngine on ``make_decode_mesh()`` (data=2, model=4)
  sharded_overlap : same, ``cfg.overlap_a2a=True`` (half-batch EP-A2A
                    overlap) — the compiled decode step's HLO is checked
                    with ``hlo_analysis.assert_a2a_overlap``

Appends the "sharded" row to BENCH_serve.json.  ``speedup_overlap``
(overlap-on vs overlap-off tok/s, same run, same machine) is the
regression-gated metric; absolute tok/s is informational.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_MARK = "BENCH_SHARDED_JSON:"
_N_DEVICES = 8


def serving_sharded_bench(log=print):
    """Parent entry: run the measurement in a fresh 8-device child and
    append its row to BENCH_serve.json."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={_N_DEVICES}"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run([sys.executable, "-m", "benchmarks.serving_sharded"],
                          capture_output=True, text=True, env=env, cwd=root,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded serving child failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    row = None
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            row = json.loads(line[len(_MARK):])
        elif line.strip():
            log(f"  {line}")
    if row is None:
        raise RuntimeError(f"child emitted no row:\n{proc.stdout}")

    path = os.path.join(root, "BENCH_serve.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["sharded"] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"  sharded: mesh={row['mesh']} "
        f"{row['modes']['sharded']['tok_s']} tok/s, overlap win "
        f"{row['speedup_overlap']}x (outputs match single-device)")
    return row


def _child_main(n_requests: int = 8, n_slots: int = 4, seg_len: int = 4,
                seed: int = 0, arch: str = "qwen2-moe-a2.7b",
                repeats: int = 2):
    import functools
    import time

    import jax
    import jax.numpy as jnp

    from benchmarks.serving import (_serve_engine_mode, _timed_replays,
                                    _traffic)
    from repro.configs import get_config
    from repro.launch import hlo_analysis as H
    from repro.launch.mesh import make_decode_mesh
    from repro.models import model as M
    from repro.serve import ServeEngine

    assert len(jax.devices()) == _N_DEVICES, jax.devices()
    cfg = get_config(arch, variant="reduced").replace(vocab_size=256)
    ocfg = cfg.replace(overlap_a2a=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batches, lengths, arrivals = _traffic(cfg, n_requests, seed)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    total_tokens = sum(g for _, g in lengths)
    mesh = make_decode_mesh()

    # structural proof first: the overlapped decode step's compiled HLO
    # has an all-to-all with dataflow-independent matmul work
    with mesh:
        ecfg = ServeEngine(params, ocfg, n_slots=n_slots, max_len=max_len,
                           mesh=mesh).cfg  # engine-forced moe_dropless
        cache = M.init_decode_cache(ecfg, n_slots, max_len, mesh=mesh)
        step = jax.jit(lambda p, c, t, q, lv: M.decode_step(
            p, ecfg, c, t, q, mesh=mesh, live=lv))
        hlo = step.lower(params, cache, jnp.zeros((n_slots, 1), jnp.int32),
                         jnp.zeros((n_slots,), jnp.int32),
                         jnp.ones((n_slots,), jnp.bool_)).compile().as_text()
    H.assert_a2a_overlap(hlo)
    n_indep = max(n for _, _, n in H.a2a_overlap_pairs(hlo))

    results, outputs = {}, {}
    for name, (mcfg, msh) in {
        "single": (cfg, None),
        "sharded": (cfg, mesh),
        "sharded_overlap": (ocfg, mesh),
    }.items():
        eng = ServeEngine(params, mcfg, n_slots=n_slots, max_len=max_len,
                          seg_len=seg_len, mesh=msh)
        fn = functools.partial(_serve_engine_mode, engine=eng)
        wall, outs, extra = _timed_replays(
            fn, params, mcfg, batches, lengths, arrivals, max_len,
            total_tokens, name, repeats)
        n_tok = sum(len(v) for v in outs.values())
        results[name] = {"wall_s": round(wall, 4),
                         "tok_s": round(n_tok / wall, 2), **extra}
        outputs[name] = outs
        print(f"{name}: {n_tok} tok in {wall:.3f}s "
              f"({results[name]['tok_s']} tok/s)")
    # greedy + dropless expert buffers: every mode must emit the SAME
    # tokens — a sharded speedup over diverging outputs is meaningless
    assert outputs["sharded"] == outputs["single"], \
        "sharded engine diverged from single-device"
    assert outputs["sharded_overlap"] == outputs["single"], \
        "overlapped engine diverged from single-device"

    row = {
        "arch": cfg.name,
        "mesh": {"data": mesh.shape["data"], "model": mesh.shape["model"]},
        "traffic": {"n_requests": n_requests, "seed": seed,
                    "total_tokens": total_tokens},
        "engine": {"n_slots": n_slots, "seg_len": seg_len,
                   "max_len": max_len},
        "modes": results,
        "outputs_match_single_device": True,
        "overlap_independent_dots": n_indep,
        # same-run, same-machine ratio: the regression-gated metric
        "speedup_overlap": round(
            results["sharded_overlap"]["tok_s"] / results["sharded"]["tok_s"],
            2),
    }
    print(_MARK + json.dumps(row))


if __name__ == "__main__":
    _child_main()
