"""Runs every method once per system size and caches metrics.

Shared by table1 (perplexity), table2 (accuracy) and fig9 (centralized
comparison) — the paper evaluates the same trained models three ways.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (cached, device_families, global_moe_cfg,
                               server_cfg, sim_cfg, store)
from repro.core.baselines import (run_centralized, run_fedjets, run_fedkmt,
                                  run_ofa_kd)
from repro.data.federated import FederatedCorpus
from repro.federated.simulation import build_fleet, run_deepfusion
from repro.federated.device import train_device, train_fleet


def _uploads_for(sim, corpus, device_cfgs, log):
    fleet = build_fleet(sim, corpus, device_cfgs)
    ups = train_fleet(fleet, corpus, steps=sim.device_steps,
                      batch=sim.device_batch, seq_len=sim.seq_len,
                      seed=sim.seed)
    for spec, up in zip(fleet, ups):
        log(f"  device {spec.device_id} arch{spec.arch_id} "
            f"dom{spec.domain_id} {up['losses'][-1]:.3f}")
    return ups


def moe_dispatch_bench(T: int = 512, D: int = 128, F: int = 256, E: int = 8,
                       k: int = 2, *, log=print):
    """Dispatch + grouped FFN + combine, before/after the fused path.

    "before" replicates the seed's moe_ffn: argsort/searchsorted routing
    plus ``.at[].add`` scatter dispatch and gather/scatter combine around
    a batched-einsum grouped FFN.  "after_fused_xla" is the shared
    permute/unpermute utility (``kernels/moe_dispatch``, XLA variant) —
    same compute, fused dispatch — and "after_fused_pallas" is the full
    Pallas ``moe_ffn`` with its custom-VJP backward (interpret-emulated
    on CPU; the pallas rows are only meaningful on TPU).  Returns
    {name: us_per_call}.
    """
    import jax
    import jax.numpy as jnp
    from benchmarks.common import timed
    from repro.kernels.moe_dispatch.ops import (capacity_positions,
                                                token_combine, token_dispatch)
    from repro.kernels.moe_gemm.ops import moe_ffn
    from repro.kernels.moe_gemm.ref import grouped_ffn_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xt = jax.random.normal(ks[0], (T, D))
    w, idx = jax.lax.top_k(jax.nn.softmax(
        jax.random.normal(ks[1], (T, E))), k)
    w = w / w.sum(-1, keepdims=True)
    wg = jax.random.normal(ks[2], (E, D, F)) * 0.1
    wu = jax.random.normal(ks[3], (E, D, F)) * 0.1
    wo = jax.random.normal(ks[4], (E, F, D)) * 0.1
    cap = max(-(-T * k // E) * 2, 8)

    def seed_dispatch(xt, w, idx):
        # the seed's argsort + scatter-add dispatch/combine, verbatim
        flat_e = idx.reshape(-1)
        flat_w = w.reshape(-1)
        flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        pos_sorted = jnp.arange(T * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                          "left")
        pos = jnp.zeros((T * k,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
        keep = pos < cap
        buf = jnp.zeros((E, cap, D), xt.dtype)
        buf = buf.at[flat_e, jnp.where(keep, pos, 0)].add(
            jnp.where(keep, 1.0, 0.0)[:, None].astype(xt.dtype)
            * xt[flat_tok])
        y = grouped_ffn_ref(buf, wg, wu, wo)
        gathered = y[flat_e, jnp.where(keep, pos, 0)]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        return jnp.zeros((T, D), xt.dtype).at[flat_tok].add(
            gathered * flat_w[:, None].astype(xt.dtype))

    def fused_xla(xt, w, idx):
        flat_e = idx.reshape(-1)
        flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
        pos, keep = capacity_positions(flat_e, cap)
        slot = flat_e * cap + pos
        buf = token_dispatch(xt, flat_tok, slot, keep, E * cap,
                             use_kernel=False)
        y = grouped_ffn_ref(buf.reshape(E, cap, D), wg, wu, wo)
        return token_combine(y.reshape(E * cap, D), flat_tok, slot, keep,
                             w.reshape(-1), T, use_kernel=False)

    out = {}
    for name, fn in (("before_argsort_scatter", seed_dispatch),
                     ("after_fused_xla", fused_xla)):
        us, _ = timed(jax.jit(fn), xt, w, idx)
        out[name] = us
        log(f"moe dispatch+ffn+combine {name}: {us:.0f}us")

    us, _ = timed(jax.jit(lambda *a: moe_ffn(*a)), xt, w, idx, wg, wu, wo)
    out["after_fused_pallas"] = us
    log(f"moe dispatch+ffn+combine after_fused_pallas: {us:.0f}us")

    grad_after = jax.jit(jax.grad(
        lambda wg: moe_ffn(xt, w, idx, wg, wu, wo).sum()))
    us, _ = timed(grad_after, wg)
    out["after_fused_pallas_backward"] = us
    log(f"moe grouped-GEMM backward (custom VJP): {us:.0f}us")
    return out


def _train_state_bytes(cfg, policy: str) -> int:
    """Persistent per-device training state (params + AdamW state) under
    a moment policy — abstract shapes only, nothing is allocated.  This
    is what bounds how many simulated devices one host can keep resident
    between fleet steps (gradients are transient inside the jitted
    epoch)."""
    import jax
    from repro.models import model as M
    from repro.optim import adamw_init

    def build():
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        return params, adamw_init(params, policy=policy)

    tree = jax.eval_shape(build)
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def fleet_opt_state_column(log=print):
    """The devices-per-host column for BENCH_fleet.json: summed resident
    training-state bytes across one device of each fleet arch, fp32
    moments vs the int8-v / bf16-m policy.  Deterministic byte counts —
    the ratio is how many MORE devices fit a fixed host budget."""
    cfgs = device_families()
    fp32 = sum(_train_state_bytes(c, "") for c in cfgs)
    int8 = sum(_train_state_bytes(c, "int8") for c in cfgs)
    col = {
        "opt_bytes_fp32": fp32,
        "opt_bytes_int8": int8,
        "state_policy": "int8 (m bf16, v int8 + per-tensor scale)",
        "devices_per_host_gain": round(fp32 / int8, 2),
    }
    log(f"fleet opt state: {fp32} B fp32 vs {int8} B int8 policy "
        f"({col['devices_per_host_gain']}x devices per host)")
    return col


def fleet_scaling_bench(sizes=(8, 32, 64), *, seed: int = 0, log=print):
    """Device-fleet wall-clock: sequential per-step loops (the seed's
    path, one host sync per step) vs the arch-bucketed vmapped
    scan-epoch driver (`train_fleet`).  Both paths train the exact same
    devices on the exact same batches; compile time is excluded by a
    warmup pass for each.  Writes BENCH_fleet.json at the repo root and
    returns its "results" dict.
    """
    import json
    import os
    import time

    results = {}
    for N in sizes:
        sim = sim_cfg(N, seed)
        dev_cfgs = device_families()
        corpus = FederatedCorpus.build(seed=sim.seed, n_devices=N,
                                       n_domains=sim.n_domains,
                                       vocab=sim.vocab,
                                       alpha=sim.alpha_noniid)
        fleet = build_fleet(sim, corpus, dev_cfgs)
        kw = dict(steps=sim.device_steps, batch=sim.device_batch,
                  seq_len=sim.seq_len, seed=sim.seed)

        def sequential():
            return [train_device(s, corpus, compiled=False, **kw)
                    for s in fleet]

        def compiled_fleet():
            return train_fleet(fleet, corpus, **kw)

        # warmup: the per-step fn compiles per cfg (one step per distinct
        # cfg suffices); the fleet epoch compiles per bucket *shape*, so
        # its warmup must run the real fleet once
        for cfg in {s.cfg for s in fleet}:
            spec = next(s for s in fleet if s.cfg is cfg)
            train_device(spec, corpus, compiled=False, steps=1,
                         batch=sim.device_batch, seq_len=sim.seq_len,
                         seed=sim.seed)
        compiled_fleet()
        t0 = time.perf_counter()
        seq_ups = sequential()
        t_seq = time.perf_counter() - t0
        t0 = time.perf_counter()
        fleet_ups = compiled_fleet()
        t_fleet = time.perf_counter() - t0
        drift = max(abs(a["losses"][-1] - b["losses"][-1])
                    for a, b in zip(seq_ups, fleet_ups))
        n_buckets = len({s.cfg for s in fleet})
        results[f"N{N}"] = {
            "sequential_s": round(t_seq, 3),
            "fleet_s": round(t_fleet, 3),
            "speedup": round(t_seq / max(t_fleet, 1e-9), 2),
            "n_buckets": n_buckets,
            "max_final_loss_drift": float(drift),
        }
        log(f"fleet N={N}: sequential {t_seq:.2f}s, vmapped {t_fleet:.2f}s "
            f"({t_seq / max(t_fleet, 1e-9):.1f}x, {n_buckets} buckets, "
            f"drift {drift:.2e})")

    import multiprocessing
    payload = {
        "bench": "fleet_scaling",
        "device_steps": sim_cfg(sizes[0], seed).device_steps,
        "device_batch": sim_cfg(sizes[0], seed).device_batch,
        "seq_len": sim_cfg(sizes[0], seed).seq_len,
        "host_cpus": multiprocessing.cpu_count(),
        "note": ("speedup = per-step Python loop (one host sync per step, "
                 "devices strictly sequential) vs arch-bucketed "
                 "vmap(scan) epochs; grows with fleet size. On a "
                 "few-core CPU host both paths saturate the cores, so the "
                 "ratio is bounded by the eliminated per-step overhead; on "
                 "parallel accelerators the bucketed batch feeds the "
                 "hardware directly and the gap widens accordingly."),
        "results": results,
        "opt_state": fleet_opt_state_column(log=log),
    }
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")
    # read-modify-write: BENCH_fleet.json is shared with the fleet_async
    # bench — only replace this bench's keys, never other rows
    existing = {}
    if os.path.exists(out):
        with open(out) as f:
            existing = json.load(f)
    existing.update(payload)
    with open(out, "w") as f:
        json.dump(existing, f, indent=1)
    return results


def run_all_methods(n_devices: int, *, log=print, seed: int = 0):
    """Returns {method: {"log_ppl", "accuracy", "comm_bytes", ...}}."""
    tag = f"methods_N{n_devices}_s{seed}"
    hit = cached(tag)
    if hit is not None:
        return hit
    sim = sim_cfg(n_devices, seed)
    scfg = server_cfg(seed)
    dev_cfgs = device_families()
    corpus = FederatedCorpus.build(seed=sim.seed, n_devices=sim.n_devices,
                                   n_domains=sim.n_domains, vocab=sim.vocab,
                                   alpha=sim.alpha_noniid)
    log(f"== N={n_devices}: local device training (shared across methods)")
    uploads = _uploads_for(sim, corpus, dev_cfgs, log)

    out = {}

    def keep(name, report):
        m = report["metrics"]
        out[name] = {"log_ppl": m["log_ppl"], "ppl": m["ppl"],
                     "accuracy": m["accuracy"],
                     "comm_bytes": int(report.get("comm_bytes", 0)),
                     # Phase II/III training curves (final losses), now
                     # recorded by DeepFusionServer.run
                     "distill_final_losses": [
                         h[-1] for h in report.get("distill_hists", [])],
                     "tune_final_loss": (report.get("tune_hist") or [None])[-1]}
        log(f"== {name}: log-ppl {m['log_ppl']:.4f} acc {m['accuracy']:.3f}")

    log("== DeepFusion")
    _, rep = run_deepfusion(sim, scfg, dev_cfgs, uploads=uploads,
                            corpus=corpus, log=log)
    keep("deepfusion", rep)

    log("== FedKMT (logits-only KD ablation)")
    _, rep = run_fedkmt(sim, scfg, dev_cfgs, uploads=uploads, corpus=corpus,
                        log=log)
    keep("fedkmt", rep)

    log("== OFA-KD (stage-exit logits alignment)")
    _, rep = run_ofa_kd(sim, scfg, dev_cfgs, uploads=uploads, corpus=corpus,
                        log=log)
    keep("ofa_kd", rep)

    log("== FedJETS (pruned per-device MoE, multi-round)")
    _, rep = run_fedjets(sim, global_moe_cfg(), rounds=3, local_steps=10,
                         batch=8, corpus=corpus, log=log)
    keep("fedjets", rep)

    log("== Centralized upper bound")
    _, rep = run_centralized(sim, global_moe_cfg(), steps=120, batch=8,
                             corpus=corpus, log=log)
    keep("centralized", rep)

    store(tag, out)
    return out
