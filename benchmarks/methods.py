"""Runs every method once per system size and caches metrics.

Shared by table1 (perplexity), table2 (accuracy) and fig9 (centralized
comparison) — the paper evaluates the same trained models three ways.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (cached, device_families, global_moe_cfg,
                               server_cfg, sim_cfg, store)
from repro.core.baselines import (run_centralized, run_fedjets, run_fedkmt,
                                  run_ofa_kd)
from repro.data.federated import FederatedCorpus
from repro.federated.simulation import build_fleet, run_deepfusion
from repro.federated.device import train_device


def _uploads_for(sim, corpus, device_cfgs, log):
    fleet = build_fleet(sim, corpus, device_cfgs)
    ups = []
    for spec in fleet:
        up = train_device(spec, corpus, steps=sim.device_steps,
                          batch=sim.device_batch, seq_len=sim.seq_len,
                          seed=sim.seed)
        ups.append(up)
        log(f"  device {spec.device_id} arch{spec.arch_id} "
            f"dom{spec.domain_id} {up['losses'][-1]:.3f}")
    return ups


def run_all_methods(n_devices: int, *, log=print, seed: int = 0):
    """Returns {method: {"log_ppl", "accuracy", "comm_bytes", ...}}."""
    tag = f"methods_N{n_devices}_s{seed}"
    hit = cached(tag)
    if hit is not None:
        return hit
    sim = sim_cfg(n_devices, seed)
    scfg = server_cfg(seed)
    dev_cfgs = device_families()
    corpus = FederatedCorpus.build(seed=sim.seed, n_devices=sim.n_devices,
                                   n_domains=sim.n_domains, vocab=sim.vocab,
                                   alpha=sim.alpha_noniid)
    log(f"== N={n_devices}: local device training (shared across methods)")
    uploads = _uploads_for(sim, corpus, dev_cfgs, log)

    out = {}

    def keep(name, report):
        m = report["metrics"]
        out[name] = {"log_ppl": m["log_ppl"], "ppl": m["ppl"],
                     "accuracy": m["accuracy"],
                     "comm_bytes": int(report.get("comm_bytes", 0))}
        log(f"== {name}: log-ppl {m['log_ppl']:.4f} acc {m['accuracy']:.3f}")

    log("== DeepFusion")
    _, rep = run_deepfusion(sim, scfg, dev_cfgs, uploads=uploads,
                            corpus=corpus, log=log)
    keep("deepfusion", rep)

    log("== FedKMT (logits-only KD ablation)")
    _, rep = run_fedkmt(sim, scfg, dev_cfgs, uploads=uploads, corpus=corpus,
                        log=log)
    keep("fedkmt", rep)

    log("== OFA-KD (stage-exit logits alignment)")
    _, rep = run_ofa_kd(sim, scfg, dev_cfgs, uploads=uploads, corpus=corpus,
                        log=log)
    keep("ofa_kd", rep)

    log("== FedJETS (pruned per-device MoE, multi-round)")
    _, rep = run_fedjets(sim, global_moe_cfg(), rounds=3, local_steps=10,
                         batch=8, corpus=corpus, log=log)
    keep("fedjets", rep)

    log("== Centralized upper bound")
    _, rep = run_centralized(sim, global_moe_cfg(), steps=120, batch=8,
                             corpus=corpus, log=log)
    keep("centralized", rep)

    store(tag, out)
    return out
