"""Runs every method once per system size and caches metrics.

Shared by table1 (perplexity), table2 (accuracy) and fig9 (centralized
comparison) — the paper evaluates the same trained models three ways.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (cached, device_families, global_moe_cfg,
                               server_cfg, sim_cfg, store)
from repro.core.baselines import (run_centralized, run_fedjets, run_fedkmt,
                                  run_ofa_kd)
from repro.data.federated import FederatedCorpus
from repro.federated.simulation import build_fleet, run_deepfusion
from repro.federated.device import train_device


def _uploads_for(sim, corpus, device_cfgs, log):
    fleet = build_fleet(sim, corpus, device_cfgs)
    ups = []
    for spec in fleet:
        up = train_device(spec, corpus, steps=sim.device_steps,
                          batch=sim.device_batch, seq_len=sim.seq_len,
                          seed=sim.seed)
        ups.append(up)
        log(f"  device {spec.device_id} arch{spec.arch_id} "
            f"dom{spec.domain_id} {up['losses'][-1]:.3f}")
    return ups


def moe_dispatch_bench(T: int = 512, D: int = 128, F: int = 256, E: int = 8,
                       k: int = 2, *, log=print):
    """Dispatch + grouped FFN + combine, before/after the fused path.

    "before" replicates the seed's moe_ffn: argsort/searchsorted routing
    plus ``.at[].add`` scatter dispatch and gather/scatter combine around
    a batched-einsum grouped FFN.  "after_fused_xla" is the shared
    permute/unpermute utility (``kernels/moe_dispatch``, XLA variant) —
    same compute, fused dispatch — and "after_fused_pallas" is the full
    Pallas ``moe_ffn`` with its custom-VJP backward (interpret-emulated
    on CPU; the pallas rows are only meaningful on TPU).  Returns
    {name: us_per_call}.
    """
    import jax
    import jax.numpy as jnp
    from benchmarks.common import timed
    from repro.kernels.moe_dispatch.ops import (capacity_positions,
                                                token_combine, token_dispatch)
    from repro.kernels.moe_gemm.ops import moe_ffn
    from repro.kernels.moe_gemm.ref import grouped_ffn_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xt = jax.random.normal(ks[0], (T, D))
    w, idx = jax.lax.top_k(jax.nn.softmax(
        jax.random.normal(ks[1], (T, E))), k)
    w = w / w.sum(-1, keepdims=True)
    wg = jax.random.normal(ks[2], (E, D, F)) * 0.1
    wu = jax.random.normal(ks[3], (E, D, F)) * 0.1
    wo = jax.random.normal(ks[4], (E, F, D)) * 0.1
    cap = max(-(-T * k // E) * 2, 8)

    def seed_dispatch(xt, w, idx):
        # the seed's argsort + scatter-add dispatch/combine, verbatim
        flat_e = idx.reshape(-1)
        flat_w = w.reshape(-1)
        flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        pos_sorted = jnp.arange(T * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                          "left")
        pos = jnp.zeros((T * k,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
        keep = pos < cap
        buf = jnp.zeros((E, cap, D), xt.dtype)
        buf = buf.at[flat_e, jnp.where(keep, pos, 0)].add(
            jnp.where(keep, 1.0, 0.0)[:, None].astype(xt.dtype)
            * xt[flat_tok])
        y = grouped_ffn_ref(buf, wg, wu, wo)
        gathered = y[flat_e, jnp.where(keep, pos, 0)]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        return jnp.zeros((T, D), xt.dtype).at[flat_tok].add(
            gathered * flat_w[:, None].astype(xt.dtype))

    def fused_xla(xt, w, idx):
        flat_e = idx.reshape(-1)
        flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
        pos, keep = capacity_positions(flat_e, cap)
        slot = flat_e * cap + pos
        buf = token_dispatch(xt, flat_tok, slot, keep, E * cap,
                             use_kernel=False)
        y = grouped_ffn_ref(buf.reshape(E, cap, D), wg, wu, wo)
        return token_combine(y.reshape(E * cap, D), flat_tok, slot, keep,
                             w.reshape(-1), T, use_kernel=False)

    out = {}
    for name, fn in (("before_argsort_scatter", seed_dispatch),
                     ("after_fused_xla", fused_xla)):
        us, _ = timed(jax.jit(fn), xt, w, idx)
        out[name] = us
        log(f"moe dispatch+ffn+combine {name}: {us:.0f}us")

    us, _ = timed(jax.jit(lambda *a: moe_ffn(*a)), xt, w, idx, wg, wu, wo)
    out["after_fused_pallas"] = us
    log(f"moe dispatch+ffn+combine after_fused_pallas: {us:.0f}us")

    grad_after = jax.jit(jax.grad(
        lambda wg: moe_ffn(xt, w, idx, wg, wu, wo).sum()))
    us, _ = timed(grad_after, wg)
    out["after_fused_pallas_backward"] = us
    log(f"moe grouped-GEMM backward (custom VJP): {us:.0f}us")
    return out


def run_all_methods(n_devices: int, *, log=print, seed: int = 0):
    """Returns {method: {"log_ppl", "accuracy", "comm_bytes", ...}}."""
    tag = f"methods_N{n_devices}_s{seed}"
    hit = cached(tag)
    if hit is not None:
        return hit
    sim = sim_cfg(n_devices, seed)
    scfg = server_cfg(seed)
    dev_cfgs = device_families()
    corpus = FederatedCorpus.build(seed=sim.seed, n_devices=sim.n_devices,
                                   n_domains=sim.n_domains, vocab=sim.vocab,
                                   alpha=sim.alpha_noniid)
    log(f"== N={n_devices}: local device training (shared across methods)")
    uploads = _uploads_for(sim, corpus, dev_cfgs, log)

    out = {}

    def keep(name, report):
        m = report["metrics"]
        out[name] = {"log_ppl": m["log_ppl"], "ppl": m["ppl"],
                     "accuracy": m["accuracy"],
                     "comm_bytes": int(report.get("comm_bytes", 0))}
        log(f"== {name}: log-ppl {m['log_ppl']:.4f} acc {m['accuracy']:.3f}")

    log("== DeepFusion")
    _, rep = run_deepfusion(sim, scfg, dev_cfgs, uploads=uploads,
                            corpus=corpus, log=log)
    keep("deepfusion", rep)

    log("== FedKMT (logits-only KD ablation)")
    _, rep = run_fedkmt(sim, scfg, dev_cfgs, uploads=uploads, corpus=corpus,
                        log=log)
    keep("fedkmt", rep)

    log("== OFA-KD (stage-exit logits alignment)")
    _, rep = run_ofa_kd(sim, scfg, dev_cfgs, uploads=uploads, corpus=corpus,
                        log=log)
    keep("ofa_kd", rep)

    log("== FedJETS (pruned per-device MoE, multi-round)")
    _, rep = run_fedjets(sim, global_moe_cfg(), rounds=3, local_steps=10,
                         batch=8, corpus=corpus, log=log)
    keep("fedjets", rep)

    log("== Centralized upper bound")
    _, rep = run_centralized(sim, global_moe_cfg(), steps=120, batch=8,
                             corpus=corpus, log=log)
    keep("centralized", rep)

    store(tag, out)
    return out
