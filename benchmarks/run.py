"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Heavyweight experiment
results are cached under experiments/bench/ (delete to re-run).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig8_comm,roofline
"""
from __future__ import annotations

import argparse
import sys
import time

ROWS = []


def emit(name: str, us: float, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _quiet(*a, **k):
    pass


def summary(bench: str, modes: dict, *, baseline: str | None = None,
            **extras):
    """One greppable line per mode at the end of each serving bench run —
    nightly logs answer "what did mode X serve tonight" with a grep for
    ``SUMMARY`` instead of parsing BENCH_serve.json.  ``baseline`` names
    the mode the per-mode speedup is computed against; ``extras`` are
    bench-level ratios appended as their own line."""
    base = modes.get(baseline, {}).get("tok_s") if baseline else None
    for name, r in modes.items():
        tok = r.get("tok_s")
        sp = f"{tok / base:.2f}x" if base and tok else "n/a"
        print(f"SUMMARY {bench} mode={name} tok_s={tok} speedup={sp}",
              flush=True)
    for key, val in extras.items():
        print(f"SUMMARY {bench} {key}={val}", flush=True)


# ---------------------------------------------------------------------------
# Tables I / II + Fig. 9 — method comparison across system scales
# ---------------------------------------------------------------------------

def table1_perplexity(sizes=(8, 16)):
    """Paper Table I: token perplexity (log) per method per N."""
    from benchmarks.methods import run_all_methods
    for n in sizes:
        t0 = time.time()
        res = run_all_methods(n, log=_quiet)
        us = (time.time() - t0) * 1e6
        for method, m in res.items():
            emit(f"table1/logppl/N{n}/{method}", us / max(len(res), 1),
                 round(m["log_ppl"], 4))


def table2_accuracy(sizes=(8, 16)):
    """Paper Table II: token accuracy (%) per method per N."""
    from benchmarks.methods import run_all_methods
    for n in sizes:
        t0 = time.time()
        res = run_all_methods(n, log=_quiet)  # cached after table1
        us = (time.time() - t0) * 1e6
        for method, m in res.items():
            emit(f"table2/acc%/N{n}/{method}", us / max(len(res), 1),
                 round(100 * m["accuracy"], 2))


def fig9_centralized(sizes=(8, 16)):
    """Paper Fig. 9: DeepFusion vs centralized upper bound (gap)."""
    from benchmarks.methods import run_all_methods
    for n in sizes:
        res = run_all_methods(n, log=_quiet)
        gap = res["deepfusion"]["log_ppl"] - res["centralized"]["log_ppl"]
        emit(f"fig9/logppl_gap_vs_centralized/N{n}", 0.0, round(gap, 4))


def ablation_vaa(sizes=(8,)):
    """§V.C ablation: VAA (deepfusion) vs logits-only (fedkmt) vs OFA."""
    from benchmarks.methods import run_all_methods
    for n in sizes:
        res = run_all_methods(n, log=_quiet)
        base = res["deepfusion"]["log_ppl"]
        emit(f"ablation/vaa_vs_fedkmt_logppl_delta/N{n}", 0.0,
             round(res["fedkmt"]["log_ppl"] - base, 4))
        emit(f"ablation/vaa_vs_ofakd_logppl_delta/N{n}", 0.0,
             round(res["ofa_kd"]["log_ppl"] - base, 4))


# ---------------------------------------------------------------------------
# Fig. 7 — on-device memory footprint (analytic, full-size configs)
# ---------------------------------------------------------------------------

def fig7_memory():
    """Peak device-training memory: DeepFusion device LLMs vs FedJETS
    pruned-MoE.  bf16 weights+grads + f32 adam (m,v) + activation est."""
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.utils.pytree import tree_size

    def train_bytes(cfg, batch=1, seq=512):
        n = tree_size(jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg)))
        weights = 2 * n            # bf16
        grads = 2 * n
        adam = 8 * n               # f32 m+v
        act = batch * seq * cfg.d_model * max(cfg.n_layers, 1) * 2
        return weights + grads + adam + act

    device_models = ["gpt2", "gpt2-medium", "tinyllama-1.1b", "olmo-1.2b",
                     "bloom-1.1b"]
    for name in device_models:
        cfg = get_config(name)
        emit(f"fig7/device_mem_GiB/{name}", 0.0,
             round(train_bytes(cfg) / 2**30, 2))
    # FedJETS local model: qwen-moe backbone + 2/60 experts
    moe = get_config("qwen2-moe-a2.7b")
    local = moe.replace(n_experts=2, top_k=2)
    emit("fig7/device_mem_GiB/fedjets-local-moe", 0.0,
         round(train_bytes(local) / 2**30, 2))
    dev_avg = sum(train_bytes(get_config(n)) for n in device_models) / 5
    emit("fig7/fedjets_vs_avg_device_ratio", 0.0,
         round(train_bytes(local) / dev_avg, 2))


# ---------------------------------------------------------------------------
# Fig. 8 — FL communication costs (analytic, full-size configs)
# ---------------------------------------------------------------------------

def fig8_comm(sizes=(16, 32, 64, 128)):
    """One-shot DeepFusion uploads (Eq. 5) vs FedJETS multi-round."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.federated.device import device_upload_bytes
    from repro.models import model as M
    from repro.utils.pytree import tree_size

    device_models = ["gpt2", "gpt2-medium", "tinyllama-1.1b", "olmo-1.2b",
                     "bloom-1.1b"]
    # Eq. 5 accounting: configured full-size model weights (bf16) + the
    # 32-float data embedding — the same helper the simulation bills with
    sizes_b = {name: device_upload_bytes(get_config(name))
               for name in device_models}
    moe = get_config("qwen2-moe-a2.7b")
    local = moe.replace(n_experts=2, top_k=2)
    n_local = tree_size(jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), local)))
    fedjets_round = 2 * 2 * n_local  # bf16, down+up per device per round
    rng = np.random.default_rng(0)
    for N in sizes:
        picks = rng.choice(device_models, size=N)
        deepfusion = int(sum(sizes_b[p] for p in picks))  # Eq. 5
        emit(f"fig8/comm_GiB/N{N}/deepfusion_oneshot", 0.0,
             round(deepfusion / 2**30, 2))
        for rounds in (1, 10):
            fedjets = int(N * rounds * fedjets_round)
            emit(f"fig8/comm_GiB/N{N}/fedjets_{rounds}rounds", 0.0,
                 round(fedjets / 2**30, 2))
            emit(f"fig8/comm_reduction%/N{N}/vs_{rounds}rounds", 0.0,
                 round(100 * (1 - deepfusion / fedjets), 1))


# ---------------------------------------------------------------------------
# kernel microbenchmarks (XLA paths on CPU; Pallas targets TPU)
# ---------------------------------------------------------------------------

def kernel_micro():
    import jax
    import jax.numpy as jnp
    from benchmarks.common import timed
    from repro.models.layers import chunked_attention
    from repro.models.ssm import ssd_chunked
    from repro.kernels.kd_loss.ref import ce_kl_ref

    key = jax.random.PRNGKey(0)
    B, S, H, D = 1, 512, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.arange(S)[None]
    fn = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, pos, pos, causal=True, q_chunk=128, k_chunk=128))
    us, _ = timed(fn, q, k, v)
    flops = 4 * B * H * S * S * D
    emit("kernel/chunked_attention_512", us,
         f"{flops / (us * 1e-6) / 1e9:.1f}GFLOPs")

    Bs2, S2, H2, P2, N2 = 1, 1024, 4, 32, 32
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (Bs2, S2, H2, P2))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs2, S2, H2)))
    A = -jnp.exp(jax.random.normal(ks[2], (H2,)) * 0.3)
    Bh = jax.random.normal(ks[3], (Bs2, S2, H2, N2)) * 0.3
    Ch = jax.random.normal(ks[4], (Bs2, S2, H2, N2)) * 0.3
    fn = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    us, _ = timed(fn, xh, dt, A, Bh, Ch)
    emit("kernel/ssd_chunked_1024", us, f"{S2}tok")

    T, Dm, V = 256, 64, 8192
    ks = jax.random.split(key, 5)
    hs = jax.random.normal(ks[0], (T, Dm))
    ws = jax.random.normal(ks[1], (Dm, V)) * 0.3
    ht = jax.random.normal(ks[2], (T, Dm))
    wt = jax.random.normal(ks[3], (Dm, V)) * 0.3
    lab = jax.random.randint(ks[4], (T,), 0, V)
    fn = jax.jit(lambda *a: ce_kl_ref(*a, tau=2.0)[1])
    us, _ = timed(fn, hs, ws, ht, wt, lab)
    emit("kernel/kd_loss_T256_V8k", us, "ce+kl")


# ---------------------------------------------------------------------------
# roofline table (reads dry-run artifacts)
# ---------------------------------------------------------------------------

def roofline():
    import glob
    import json
    import os
    here = os.path.dirname(__file__)
    pat = os.path.join(here, "..", "experiments", "dryrun", "*_16x16.json")
    files = sorted(glob.glob(pat))
    if not files:
        emit("roofline/no_dryrun_artifacts_found", 0.0, 0)
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        name = f"roofline/{rec['arch']}/{rec['shape']}"
        if rec.get("status") == "SKIP":
            emit(name, 0.0, "SKIP:" + rec.get("reason", "")[:40])
            continue
        if rec.get("status") != "OK":
            emit(name, 0.0, "FAIL")
            continue
        t = rec["roofline"]
        emit(name, t["t_compute_s"] * 1e6,
             f"dom={t['dominant']};tc={t['t_compute_s']:.2e}s;"
             f"tm={t['t_memory_s']:.2e}s;tx={t['t_collective_s']:.2e}s")


def kernel_moe_dispatch():
    """Dispatch+FFN+combine before/after the fused MoE path."""
    from benchmarks.methods import moe_dispatch_bench
    for name, us in moe_dispatch_bench(log=_quiet).items():
        emit(f"kernel/moe_dispatch/{name}", us, "T512_D128_E8_k2")


def serving():
    """Serving throughput: python-loop vs scanned decode vs continuous
    batching on Poisson mixed-length traffic.  Writes BENCH_serve.json."""
    from benchmarks.serving import serving_bench
    res = serving_bench(log=_quiet)
    for mode, row in res["modes"].items():
        emit(f"serve/{mode}", row["wall_s"] * 1e6, f"{row['tok_s']}tok/s")
    emit("serve/speedup_scan_vs_loop", 0.0, res["speedup_scan_vs_loop"])
    emit("serve/speedup_cb_vs_loop", 0.0, res["speedup_cb_vs_loop"])
    summary("serving", res["modes"], baseline="python_loop")


def serving_paged():
    """Equal-cache-bytes capacity: contiguous slots vs the block-paged
    engine on shared-preamble traffic.  Appends the "paged" row to
    BENCH_serve.json."""
    from benchmarks.serving import serving_paged_bench
    row = serving_paged_bench(log=_quiet)
    for name in ("contiguous", "paged_engine"):
        emit(f"serve_paged/{name}", row[name]["wall_s"] * 1e6,
             f"peak_live={row[name]['peak_live_requests']};"
             f"bytes={row[name]['cache_bytes']}")
    emit("serve_paged/shared_blocks", 0.0,
         row["paged_engine"]["shared_blocks"])
    summary("serving_paged",
            {"contiguous": row["contiguous"], "paged": row["paged_engine"]},
            baseline="contiguous", concurrency_gain=row["concurrency_gain"],
            cache_bytes=f"{row['paged_engine']['cache_bytes']}/"
                        f"{row['contiguous']['cache_bytes']}")


def serving_quantized():
    """Equal-cache-bytes capacity: fp32 paged vs int8-KV paged reading
    through the fused-dequant Pallas kernel, greedy outputs asserted
    identical.  Appends the "quantized" row to BENCH_serve.json."""
    from benchmarks.serving import serving_quantized_bench
    row = serving_quantized_bench(log=_quiet)
    for name in ("paged_fp32", "paged_quantized"):
        emit(f"serve_quant/{name}", row[name]["wall_s"] * 1e6,
             f"peak_live={row[name]['peak_live_requests']};"
             f"bytes={row[name]['cache_bytes']}")
    emit("serve_quant/concurrency_gain_quant", 0.0,
         row["concurrency_gain_quant"])
    summary("serving_quantized",
            {"paged_fp32": row["paged_fp32"],
             "paged_quantized": row["paged_quantized"]},
            baseline="paged_fp32",
            concurrency_gain_quant=row["concurrency_gain_quant"],
            kv_dtype=row["kv_dtype"], read_path=row["read_path"],
            cache_bytes=f"{row['paged_quantized']['cache_bytes']}/"
                        f"{row['paged_fp32']['cache_bytes']}")


def serving_bucketed():
    """Compile-count bench: open-world mixed-length traffic through the
    unbucketed vs bucketed (chunked-prefill) engines.  Appends the
    "bucketed" row to BENCH_serve.json."""
    from benchmarks.serving import serving_bucketed_bench
    row = serving_bucketed_bench(log=_quiet)
    for name, r in row["modes"].items():
        emit(f"serve_bucketed/{name}", r["wall_s"] * 1e6,
             f"compiles={r['compiles']};{r['tok_s']}tok/s")
    emit("serve_bucketed/n_buckets", 0.0, len(row["engine"]["buckets"]))
    emit("serve_bucketed/n_distinct_lengths", 0.0,
         row["traffic"]["n_distinct_lengths"])
    summary("serving_bucketed", row["modes"], baseline="unbucketed",
            compile_reduction_ratio=row["compile_reduction_ratio"])


def serving_sharded():
    """Decode-mesh serving (8 fake CPU devices in a child process):
    sharded vs single-device tok/s and the EP-A2A overlap win.  Appends
    the "sharded" row to BENCH_serve.json."""
    from benchmarks.serving_sharded import serving_sharded_bench
    row = serving_sharded_bench(log=_quiet)
    for name, r in row["modes"].items():
        emit(f"serve_sharded/{name}", r["wall_s"] * 1e6,
             f"{r['tok_s']}tok/s")
    emit("serve_sharded/speedup_overlap", 0.0, row["speedup_overlap"])
    emit("serve_sharded/overlap_independent_dots", 0.0,
         row["overlap_independent_dots"])
    summary("serving_sharded", row["modes"], baseline="single",
            speedup_overlap=row["speedup_overlap"])


def serving_speculative():
    """Self-speculative MTP decode (draft k + verify in one compiled
    step) vs plain continuous batching, greedy outputs asserted
    identical.  Appends the "speculative" row to BENCH_serve.json."""
    from benchmarks.serving import serving_speculative_bench
    row = serving_speculative_bench(log=_quiet)
    for name, r in row["modes"].items():
        emit(f"serve_spec/{name}", r["wall_s"] * 1e6, f"{r['tok_s']}tok/s")
    emit("serve_spec/acceptance_rate", 0.0, row["acceptance_rate"])
    emit("serve_spec/speedup_spec_vs_cb", 0.0, row["speedup_spec_vs_cb"])
    summary("serving_speculative", row["modes"], baseline="continuous",
            acceptance_rate=row["acceptance_rate"],
            outputs_match_unspeculated=row["outputs_match_unspeculated"])


def fleet_scaling(sizes=(8, 32, 64)):
    """Device-fleet wall-clock: sequential per-step loops vs the
    vmapped scan-epoch driver.  Also writes BENCH_fleet.json."""
    from benchmarks.methods import fleet_opt_state_column, fleet_scaling_bench
    for n, row in fleet_scaling_bench(sizes, log=_quiet).items():
        emit(f"fleet/{n}/sequential", row["sequential_s"] * 1e6,
             f"{row['n_buckets']}buckets")
        emit(f"fleet/{n}/vmapped", row["fleet_s"] * 1e6,
             f"speedup={row['speedup']}x")
    col = fleet_opt_state_column(log=_quiet)
    emit("fleet/devices_per_host_gain", 0.0, col["devices_per_host_gain"])
    emit("fleet/opt_bytes_int8_vs_fp32", 0.0,
         f"{col['opt_bytes_int8']}/{col['opt_bytes_fp32']}")


def fleet_async():
    """Async participation rounds vs one-shot sync, plus the multi-host
    resident-state scaling column.  Merges into BENCH_fleet.json (runs
    in a 4-fake-host child process, see benchmarks/fleet_async.py)."""
    from benchmarks.fleet_async import fleet_async_bench
    row = fleet_async_bench(log=_quiet)
    modes = row["modes"]
    for name in ("sync", "async_ideal", "async_stragglers"):
        r = modes[name]
        emit(f"fleet_async/{name}", r["wall_s"] * 1e6,
             f"participation={r.get('participation_rate', 1.0)}")
    emit("fleet_async/devices_per_host_scaling", 0.0,
         f"{row['devices_per_host_scaling']}x")
    for name in ("async_ideal", "async_stragglers"):
        r = modes[name]
        print(f"SUMMARY fleet_async mode={name} "
              f"rounds_per_s={r['rounds_per_s']} "
              f"participation={r['participation_rate']} "
              f"staleness_p95={r.get('staleness_p95', 0.0)}", flush=True)
    print(f"SUMMARY fleet_async stale_merge_overhead="
          f"{modes['async_ideal']['stale_merge_overhead']}x "
          f"devices_per_host_scaling={row['devices_per_host_scaling']}x",
          flush=True)


ALL_BENCHES = {
    "table1_perplexity": table1_perplexity,
    "table2_accuracy": table2_accuracy,
    "fig7_memory": fig7_memory,
    "fig8_comm": fig8_comm,
    "fig9_centralized": fig9_centralized,
    "ablation_vaa": ablation_vaa,
    "kernel_micro": kernel_micro,
    "kernel_moe_dispatch": kernel_moe_dispatch,
    "fleet_scaling": fleet_scaling,
    "fleet_async": fleet_async,
    "serving": serving,
    "serving_paged": serving_paged,
    "serving_quantized": serving_quantized,
    "serving_bucketed": serving_bucketed,
    "serving_sharded": serving_sharded,
    "serving_speculative": serving_speculative,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n.strip() for n in args.only.split(",") if n.strip()] or \
        list(ALL_BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        ALL_BENCHES[n]()


if __name__ == "__main__":
    main()
