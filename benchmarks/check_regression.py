"""Benchmark regression gate for the nightly workflow.

Compares the freshly-written ``BENCH_*.json`` files against the
checked-in baseline snapshot and fails (exit 1) when any
higher-is-better metric dropped by more than ``--threshold`` (default
25%).  Only metric paths present in BOTH files are compared, so adding
a new benchmark row never breaks the gate — it just starts being
enforced once a baseline containing it is checked in.

  python benchmarks/check_regression.py \
      --baseline /tmp/bench-baseline --current . --threshold 0.25
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BENCH_FILES = ("BENCH_serve.json", "BENCH_fleet.json")
# Gated metrics must transfer across machines: the checked-in baseline
# is produced on a developer box while the nightly runs on a CI runner,
# so absolute wall/throughput numbers would gate on runner speed, not
# code.  HIGHER-is-better: same-run speedup ratios and deterministic
# capacity/compile-reduction ratios.  LOWER-is-better: executable build
# counts (deterministic — any growth is a real compile-bound
# regression) and byte footprints (cache layouts and quantized
# optimizer state are pure functions of the config — any growth means
# a storage-policy regression).  Absolute tok_s is reported as INFO
# only; its regressions surface through the speedup ratios computed
# in-run.
HIGHER_KEYS = ("speedup", "concurrency_gain", "compile_reduction",
               "acceptance_rate", "devices_per_host", "participation_rate")
LOWER_KEYS = ("compiles", "cache_bytes", "opt_bytes",
              "stale_merge_overhead")
INFO_KEYS = ("tok_s",)


def _walk(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, path + (k,))
    elif isinstance(tree, (int, float)) and not isinstance(tree, bool):
        yield path, float(tree)


def _direction(key: str):
    if any(key.startswith(m) for m in HIGHER_KEYS):
        return "higher"
    if any(key.startswith(m) for m in LOWER_KEYS):
        return "lower"
    if any(key.startswith(m) for m in INFO_KEYS):
        return "info"
    return None


def metrics(tree):
    return {p: (v, _direction(p[-1])) for p, v in _walk(tree)
            if p and _direction(p[-1])}


def compare(baseline: dict, current: dict, threshold: float, label: str):
    base_m, cur_m = metrics(baseline), metrics(current)
    failures, checked = [], 0
    for path, (base, direction) in sorted(base_m.items()):
        entry = cur_m.get(path)
        if entry is None or base <= 0:
            continue
        cur = entry[0]
        ratio = cur / base
        if direction == "info":
            print(f"  {'INFO':10s} {label}:{'/'.join(path)}  "
                  f"base={base:.2f} cur={cur:.2f} ({ratio:.2f}x, "
                  f"not gated: machine-dependent)")
            continue
        checked += 1
        bad = (ratio < 1.0 - threshold if direction == "higher"
               else ratio > 1.0 + threshold)
        status = "REGRESSION" if bad else "OK"
        if bad:
            failures.append((path, base, cur, ratio))
        print(f"  {status:10s} {label}:{'/'.join(path)}  "
              f"base={base:.2f} cur={cur:.2f} ({ratio:.2f}x, "
              f"{direction} is better)")
    return checked, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory holding the baseline BENCH_*.json")
    ap.add_argument("--current", required=True,
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional drop (0.25 = 25%%)")
    args = ap.parse_args()

    total_checked, all_failures = 0, []
    for name in BENCH_FILES:
        bpath = os.path.join(args.baseline, name)
        cpath = os.path.join(args.current, name)
        if not os.path.exists(bpath):
            print(f"  SKIP       {name}: no baseline")
            continue
        if not os.path.exists(cpath):
            print(f"  MISSING    {name}: benchmark did not produce it")
            all_failures.append(((name,), 1.0, 0.0, 0.0))
            continue
        with open(bpath) as f:
            baseline = json.load(f)
        with open(cpath) as f:
            current = json.load(f)
        checked, failures = compare(baseline, current, args.threshold, name)
        total_checked += checked
        all_failures.extend(failures)

    print(f"{total_checked} metrics checked, {len(all_failures)} regressions "
          f"(threshold {args.threshold:.0%})")
    if total_checked == 0:
        print("no comparable metrics found — refusing to pass an empty gate")
        return 1
    return 1 if all_failures else 0


if __name__ == "__main__":
    sys.exit(main())
