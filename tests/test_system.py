"""End-to-end behaviour tests for the DeepFusion system (paper Fig. 3).

Runs the complete pipeline — device fleet training, one-shot upload,
clustering, VAA distillation, MoE merge, frozen-expert tuning — at tiny
scale, and checks the paper's qualitative claims hold on synthetic data:
 * the pipeline produces a working global MoE (finite ppl, better than init)
 * one-shot comm cost equals sum of device model sizes (Eq. 5)
 * VAA (feature) distillation is at least as good as logits-only KD
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federated.server import ServerConfig
from repro.federated.simulation import (SimulationConfig, evaluate_model,
                                        run_deepfusion)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.utils.pytree import tree_bytes

V = 256
SMALL = dict(vocab_size=V, dtype="float32", remat=False,
             attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32)


@pytest.fixture(scope="module")
def pipeline_result():
    dev_a = ModelConfig(name="gpt2-tiny", n_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=4, head_dim=16, d_ff=128,
                        norm_type="layernorm", act="gelu", mlp_gated=False,
                        pos_embedding="sinusoidal", **SMALL).validate()
    dev_b = ModelConfig(name="llama-tiny", n_layers=3, d_model=96, n_heads=4,
                        n_kv_heads=2, head_dim=24, d_ff=192,
                        **SMALL).validate()
    moe_cfg = ModelConfig(name="moe-tiny", arch_type="moe", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                          d_ff=128, n_experts=4, top_k=2, moe_d_ff=128,
                          n_shared_experts=1, **SMALL).validate()
    sim = SimulationConfig(n_devices=6, n_domains=4, vocab=V, seq_len=48,
                           device_steps=25, device_batch=8, seed=0)
    scfg = ServerConfig(moe_cfg=moe_cfg, distill_steps=25, distill_batch=8,
                        tune_steps=25, tune_batch=8, seq_len=48, n_stages=2,
                        p_q=32, vaa_dim=64)
    params, report = run_deepfusion(sim, scfg, [dev_a, dev_b],
                                    log=lambda s: None)
    return dict(params=params, report=report, sim=sim, scfg=scfg,
                dev_cfgs=[dev_a, dev_b], moe_cfg=moe_cfg)


def test_pipeline_produces_finite_metrics(pipeline_result):
    m = pipeline_result["report"]["metrics"]
    assert np.isfinite(m["log_ppl"])
    assert m["log_ppl"] < np.log(V)  # better than uniform
    assert 0 <= m["accuracy"] <= 1


def test_oneshot_comm_equals_sum_of_uploads(pipeline_result):
    rep = pipeline_result["report"]
    uploads = rep["uploads"]
    expect = sum(tree_bytes(u["params"]) + 32 * 4 for u in uploads)
    assert rep["comm_bytes"] == expect  # Eq. 5


def test_report_records_phase_histories(pipeline_result):
    # Phase II per-proxy distill curves + Phase III tune curve must be
    # surfaced in the report (previously computed and dropped)
    rep = pipeline_result["report"]
    scfg = pipeline_result["scfg"]
    assert len(rep["distill_hists"]) == rep["n_clusters"]
    for h in rep["distill_hists"]:
        assert len(h) == scfg.distill_steps
        assert all(np.isfinite(x) for x in h)
    assert len(rep["tune_hist"]) == scfg.tune_steps
    assert all(np.isfinite(x) for x in rep["tune_hist"])


def test_trainable_fraction_small(pipeline_result):
    # §IV.D: experts frozen -> only a minority of params train in Phase III
    assert pipeline_result["report"]["trainable_fraction"] < 0.5


def test_cluster_count_bounded_by_experts(pipeline_result):
    rep = pipeline_result["report"]
    assert 1 <= rep["n_clusters"] <= \
        pipeline_result["moe_cfg"].n_experts


def test_global_moe_beats_untrained_init(pipeline_result):
    moe_cfg = pipeline_result["moe_cfg"]
    corpus = pipeline_result["report"]["corpus"]
    fresh = M.init_params(jax.random.PRNGKey(123), moe_cfg)
    fresh_m = evaluate_model(fresh, moe_cfg, corpus, seq_len=48)
    got = pipeline_result["report"]["metrics"]["log_ppl"]
    # 25-step budgets at tiny scale give small but consistent gains
    assert got < fresh_m["log_ppl"] - 0.005, \
        f"distilled {got} vs fresh {fresh_m['log_ppl']}"


def test_vaa_not_worse_than_logits_only(pipeline_result):
    """§V.C claim: feature-level (VAA) KD >= logits-only KD.  We allow a
    small tolerance — at tiny scale the effect size is small."""
    from repro.core.baselines import run_fedkmt
    rep = pipeline_result["report"]
    _, rep_kmt = run_fedkmt(pipeline_result["sim"], pipeline_result["scfg"],
                            pipeline_result["dev_cfgs"],
                            uploads=rep["uploads"], corpus=rep["corpus"],
                            log=lambda s: None)
    assert rep["metrics"]["log_ppl"] <= \
        rep_kmt["metrics"]["log_ppl"] + 0.02
