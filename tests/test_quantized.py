"""Quantized storage-policy tests (ISSUE 9).

Covers:
  * quantize/dequantize round-trip error bounds (int8 half-step, fp8
    e4m3 half-ulp) and the per-(position, kv-head) scale layout;
  * the fused-dequant Pallas paged-attention kernel against the
    DEQUANTIZED gather oracle — GQA, softcap, sliding window, and
    C > 1 multi-query chunks;
  * cache-policy structure: scale siblings carry the policy, recurrent
    caches opt out, byte accounting shrinks accordingly;
  * the paged engine under an int8 policy emits the fp32 engine's
    greedy tokens bit-for-bit;
  * AdamW moment policies: state dtypes / freeze-mask interplay, the
    log-codebook v round trip, and bf16 / int8 policies tracking the
    fp32 scan epoch on a real device-training loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.federated import FederatedCorpus
from repro.federated.device import DeviceSpec, train_device
from repro.models import model as M
from repro.models import quant
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.serve import PagedServeEngine

V = 64
CFG = ModelConfig(name="quant-tiny", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=V,
                  dtype="float32", remat=False, attn_chunk_q=16,
                  attn_chunk_k=16, loss_chunk=16).validate()


@pytest.fixture(scope="module")
def corpus():
    return FederatedCorpus.build(seed=0, n_devices=3, n_domains=2, vocab=V)


# ---------------------------------------------------------------------------
# round-trip bounds
# ---------------------------------------------------------------------------

def test_kv_round_trip_int8_half_step_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 7, 3, 16))
    # rows at wildly different magnitudes: the per-row scale must absorb
    x = x * (10.0 ** jnp.arange(-3, 2)[:, None, None, None])
    q, s = quant.quantize(x, "int8")
    assert q.dtype == jnp.int8 and s.shape == x.shape[:-1]
    err = np.abs(np.asarray(quant.dequantize(q, s)) - np.asarray(x))
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    # symmetric round-to-nearest: half a quantization step per element
    assert np.all(err <= amax / (2 * 127) + 1e-9)


def test_kv_round_trip_fp8_half_ulp_bound():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 2, 32))
    q, s = quant.quantize(x, "fp8")
    assert s.shape == x.shape[:-1]
    err = np.abs(np.asarray(quant.dequantize(q, s)) - np.asarray(x))
    # e4m3: 3 mantissa bits -> half-ulp 2^-4 relative for normals, plus
    # the subnormal absolute floor (2^-9 at the scaled range)
    bound = np.abs(np.asarray(x)) * 2.0 ** -4 \
        + np.asarray(s)[..., None] * 2.0 ** -9 + 1e-9
    assert np.all(err <= bound)


def test_kv_round_trip_zero_rows_exact():
    x = jnp.zeros((2, 3, 4, 8))
    for kv in ("int8", "fp8"):
        q, s = quant.quantize(x, kv)
        assert np.all(np.asarray(quant.dequantize(q, s)) == 0.0)


# ---------------------------------------------------------------------------
# fused-dequant kernel vs dequantized gather oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_paged_attention_matches_dequantized_ref(kv_dtype):
    from repro.kernels.paged_attn.ops import paged_decode_attention
    from repro.kernels.paged_attn.ref import paged_attention_ref
    rng = np.random.default_rng(0)
    for (B, C, H, KH, D, nb, bl, nbt), window, softcap in [
            ((3, 1, 8, 4, 32, 10, 4, 4), 0, 0.0),   # GQA decode
            ((2, 1, 4, 4, 16, 8, 8, 3), 0, 30.0),   # MHA + softcap
            ((4, 1, 8, 2, 32, 12, 4, 5), 6, 0.0),   # sliding window
            ((2, 3, 8, 4, 16, 10, 4, 4), 0, 0.0)]:  # C>1 verify chunk
        q = jnp.asarray(rng.normal(size=(B, C, H, D)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(nb, bl, KH, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(nb, bl, KH, D)), jnp.float32)
        kq, ks = quant.quantize(kp, kv_dtype)
        vq, vs = quant.quantize(vp, kv_dtype)
        bt = jnp.asarray(rng.integers(0, nb, size=(B, nbt)), jnp.int32)
        pos = jnp.asarray(rng.integers(0, nbt * bl - C + 1, size=(B,)),
                          jnp.int32)
        out = paged_decode_attention(q, kq, vq, bt, pos, window=window,
                                     softcap=softcap, k_scale=ks, v_scale=vs,
                                     out_dtype=jnp.float32)
        # the oracle sees PRE-dequantized fp32 pools: agreement proves
        # the kernel's in-register dequant is exactly scale * q
        ref = paged_attention_ref(q, quant.dequantize(kq, ks),
                                  quant.dequantize(vq, vs), bt, pos,
                                  window=window, softcap=softcap,
                                  out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_quantized_paged_attention_requires_both_scales():
    from repro.kernels.paged_attn.ops import paged_decode_attention
    q = jnp.zeros((1, 1, 2, 8))
    kp = vp = jnp.zeros((4, 4, 2, 8))
    ks = jnp.ones((4, 4, 2))
    bt = jnp.zeros((1, 2), jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError, match="k_scale"):
        paged_decode_attention(q, kp, vp, bt, pos, k_scale=ks)


# ---------------------------------------------------------------------------
# cache policy structure
# ---------------------------------------------------------------------------

def test_cache_policy_structure_and_bytes():
    cfg = get_config("qwen2-moe-a2.7b", variant="reduced")
    pol = quant.CachePolicy("int8")
    cache = M.init_decode_cache(cfg, 2, 16, policy=pol)
    # structure carries policy: scale siblings name the storage dtype
    assert quant.policy_of(cache).kv_dtype == "int8"
    assert quant.policy_of(M.init_decode_cache(cfg, 2, 16)).kv_dtype == ""
    # int8 KV + f32 per-position scales ~= 25-30% of fp32 bytes
    assert M.cache_nbytes(cfg, 2, 16, policy=pol) \
        < 0.35 * M.cache_nbytes(cfg, 2, 16)
    assert M.paged_cache_nbytes(cfg, 2, 8, 4, policy=pol) \
        < 0.35 * M.paged_cache_nbytes(cfg, 2, 8, 4)


def test_recurrent_cache_opts_out_of_quantization():
    cfg = get_config("mamba2-1.3b", variant="reduced")
    pol = quant.CachePolicy("int8")
    cache = M.init_decode_cache(cfg, 2, 16, policy=pol)
    # ssm state is an accumulator, not append-once KV: policy is a no-op
    assert quant.policy_of(cache).kv_dtype == ""
    assert M.cache_nbytes(cfg, 2, 16, policy=pol) \
        == M.cache_nbytes(cfg, 2, 16)


def test_paged_engine_int8_matches_fp32_greedy():
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(6, 4), (9, 6), (6, 5)]
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(10 + i),
                                             (1, p), 0, cfg.vocab_size)}
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    outs = {}
    for kv in ("", "int8"):
        eng = PagedServeEngine(params, cfg, n_slots=2, max_len=max_len,
                               seg_len=3, block_len=4, seed=0, kv_dtype=kv)
        for b, (_, g) in zip(batches, lengths):
            eng.submit(b, max_new=g)
        outs[kv] = {u: c.tokens.tolist() for u, c in eng.run().items()}
    assert outs["int8"] == outs[""]


# ---------------------------------------------------------------------------
# optimizer moment policies
# ---------------------------------------------------------------------------

def test_adamw_policy_state_dtypes_and_freeze_mask():
    params = {"w": jnp.ones((4, 8)), "b": jnp.ones((8,))}
    mask = {"w": True, "b": False}
    st = adamw_init(params, freeze_mask=mask, policy="int8")
    assert st["m"]["w"].dtype == jnp.bfloat16
    assert st["v"]["w"].dtype == jnp.int8
    assert st["v_scale"]["w"].dtype == jnp.float32
    assert st["v_scale"]["w"].shape == ()
    # frozen leaves keep scalar zero moments under any policy
    assert st["m"]["b"].shape == () and st["v"]["b"].shape == ()
    # default policy: unchanged legacy fp32 layout, no scale tree
    st0 = adamw_init(params)
    assert "v_scale" not in st0 and st0["v"]["w"].dtype == jnp.float32


def test_v_log_codebook_round_trip():
    key = jax.random.PRNGKey(2)
    # second moments span decades; include exact zeros (fresh state)
    v = jax.random.uniform(key, (512,)) ** 8 * 1e-3
    v = v.at[:16].set(0.0)
    q, s = quant.quantize_v(v)
    deq = np.asarray(quant.dequantize_v(q, s))
    vn = np.asarray(v)
    assert np.all(deq[:16] == 0.0)                  # zeros bit-exact
    # code 1 decodes sqrt(v) = scale * exp(-alpha * 126/127): the floor
    v_floor = float(s) ** 2 * np.exp(-2 * quant._V_ALPHA * 126.0 / 127.0)
    live = vn >= v_floor
    rel = np.abs(deq[live & (vn > 0)] - vn[live & (vn > 0)]) \
        / vn[live & (vn > 0)]
    # half a log level: exp(alpha/127) ~ 1.115 spacing on sqrt(v) ->
    # ~11.5% worst-case relative error on v
    assert np.max(rel) <= 0.12
    # sub-floor entries saturate UP to code 1 (conservative smaller
    # Adam steps, never an eps-denominator blowup)
    sub = (vn > 0) & ~live
    assert sub.any() and np.all(deq[sub] >= vn[sub])


def test_moment_policies_track_fp32_scan_epoch(corpus):
    spec = DeviceSpec(0, CFG, 0, 0)
    kw = dict(steps=8, batch=4, seq_len=16, seed=0)
    ref = train_device(spec, corpus, compiled=True, **kw)
    bf = train_device(spec, corpus, compiled=True, state_policy="bf16", **kw)
    i8 = train_device(spec, corpus, compiled=True, state_policy="int8", **kw)
    ref_l = np.asarray(ref["losses"])
    # bf16 moments: the EMA arithmetic still runs in fp32 master
    # precision, only storage rounds — losses stay within bf16 noise
    np.testing.assert_allclose(np.asarray(bf["losses"]), ref_l, atol=2e-2)
    # int8-v log codebook: ~11% per-step v error, but the update is
    # self-correcting (overestimates shrink steps) — the trajectory
    # tracks fp32 instead of diverging like a linear codebook would
    np.testing.assert_allclose(np.asarray(i8["losses"]), ref_l, atol=5e-2)
