"""Serving-engine tests: scanned decode equivalence + slot admission.

Covers (ISSUE 3):
  * scanned ``generate`` is bit-identical to the per-token Python
    decode loop for one arch per cache family (dense/moe, ssm, hybrid,
    vlm, encdec);
  * slot-admission properties: no slot serves two requests within one
    segment, freed slots are refilled, per-slot outputs equal solo runs;
  * EOS stopping and segment-length-invariant sampling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import Greedy, ServeEngine, Temperature

# one arch per decode-cache family
FAMILY_ARCHS = [
    "qwen2-moe-a2.7b",   # dense/moe: stacked KV blocks
    "mamba2-1.3b",       # ssm: recurrent state + conv tail
    "zamba2-7b",         # hybrid: shared-attn KV + mamba groups
    "paligemma-3b",      # vlm: patch-offset KV
    "whisper-small",     # encdec: self KV + fixed cross/memory
]


def family_batch(cfg, B, P, seed=3):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, P), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    dt = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["patches"] = (jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (B, cfg.frontend_tokens, cfg.d_model)) * 0.05).astype(dt)
    if cfg.arch_type == "encdec":
        batch["frames"] = (jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (B, cfg.frontend_tokens, cfg.d_model)) * 0.05).astype(dt)
    return batch


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_generate_bit_identical_to_python_loop(arch):
    cfg = get_config(arch, variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    B, P, G = 2, 8, 4
    batch = family_batch(cfg, B, P)
    logits0, pc = M.prefill(params, cfg, batch)
    cap = M.decode_capacity(cfg, P, G + 1)
    pos0 = M.decode_pos0(cfg, P)

    # reference: per-token Python loop, one jit dispatch per step
    cache = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, B, cap), pc)
    step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
    tok = jnp.argmax(logits0, -1)[:, None].astype(jnp.int32)
    loop_toks, loop_logits = [], []
    for i in range(G):
        lg, cache = step(params, cache, tok,
                         jnp.full((B,), pos0 + i, jnp.int32))
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        loop_toks.append(np.asarray(tok[:, 0]))
        loop_logits.append(np.asarray(lg))

    # scanned: the whole loop as one dispatch
    cache = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, B, cap), pc)
    res = M.generate(params, cfg, cache, jnp.argmax(logits0, -1),
                     jnp.full((B,), pos0), steps=G, return_logits=True)
    np.testing.assert_array_equal(np.asarray(res["tokens"]),
                                  np.stack(loop_toks, 1))
    np.testing.assert_array_equal(np.asarray(res["logits"]),
                                  np.stack(loop_logits, 1))  # bit-identical
    assert np.asarray(res["valid"]).all()


def _solo_tokens(params, cfg, batch, g, max_len, uid, base_key,
                 sampler=Greedy()):
    """Reference: serve one request alone through prefill + generate,
    with the engine's per-request key protocol."""
    P = batch["tokens"].shape[1]
    logits, pc = M.prefill(params, cfg, batch)
    cache = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, 1, max_len), pc)
    key = jax.random.fold_in(base_key, uid)
    key, k0 = jax.random.split(key)
    e0 = int(np.asarray(sampler(k0[None], logits))[0])
    toks = [e0]
    if g > 1:
        res = M.generate(params, cfg, cache, jnp.asarray([e0]),
                         jnp.asarray([M.decode_pos0(cfg, P)]), steps=g - 1,
                         sampler=sampler, rng=key[None],
                         remaining=jnp.asarray([g - 1]))
        toks += np.asarray(res["tokens"])[0][
            np.asarray(res["valid"])[0]].tolist()
    return toks


def test_slot_admission_properties():
    """2 slots, 5 mixed-length requests: slot bookkeeping + solo match."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    lengths = [(6, 4), (10, 7), (7, 5), (12, 6), (9, 3)]
    batches = [{"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (1, p)), jnp.int32)}
        for p, _ in lengths]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)

    eng = ServeEngine(params, cfg, n_slots=2, max_len=max_len, seg_len=3,
                      seed=0)
    for b, (_, g) in zip(batches, lengths):
        eng.submit(b, max_new=g)
    comps = eng.run()

    # every request completed, with exactly max_new tokens
    assert sorted(comps) == list(range(5))
    for uid, (_, g) in enumerate(lengths):
        assert len(comps[uid].tokens) == g

    # no slot serves two requests within one segment
    seg_slot = [(seg, slot) for seg, slot, _ in eng.history]
    assert len(seg_slot) == len(set(seg_slot))
    # a request stays on ONE slot for its whole lifetime
    slot_of = {}
    for _, slot, uid in eng.history:
        assert slot_of.setdefault(uid, slot) == slot
    # freed slots are refilled: 5 requests through 2 slots
    uids_per_slot = {}
    for _, slot, uid in eng.history:
        uids_per_slot.setdefault(slot, set()).add(uid)
    assert max(len(v) for v in uids_per_slot.values()) >= 2

    # per-slot outputs equal solo runs (slot independence)
    for uid, (b, (_, g)) in enumerate(zip(batches, lengths)):
        solo = _solo_tokens(params, cfg, b, g, max_len, uid,
                            jax.random.PRNGKey(0))
        assert comps[uid].tokens.tolist() == solo, uid


def test_engine_eos_stops_early():
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32)}
    max_len = M.decode_capacity(cfg, 8, 8)
    solo = _solo_tokens(params, cfg, batch, 8, max_len, 0,
                        jax.random.PRNGKey(0))
    eos = solo[2]  # force an early stop on the 3rd greedy token
    eng = ServeEngine(params, cfg, n_slots=1, max_len=max_len, seg_len=4,
                      seed=0, eos_id=eos)
    eng.submit(batch, max_new=8)
    comps = eng.run()
    assert comps[0].tokens.tolist() == solo[:3]  # EOS token included


def test_engine_sampling_invariant_to_segment_length():
    """Temperature sampling must not depend on how the decode is cut
    into segments (per-slot keys split once per step, live or not)."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    lengths = [(6, 5), (9, 7), (5, 4)]
    batches = [{"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (1, p)), jnp.int32)}
        for p, _ in lengths]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    outs = []
    for seg_len in (2, 5):
        eng = ServeEngine(params, cfg, n_slots=2, max_len=max_len,
                          seg_len=seg_len, seed=7, sampler=Temperature(0.8))
        for b, (_, g) in zip(batches, lengths):
            eng.submit(b, max_new=g)
        comps = eng.run()
        outs.append({u: c.tokens.tolist() for u, c in comps.items()})
    assert outs[0] == outs[1]


def test_engine_serves_encdec():
    """whisper through the engine end-to-end (no SystemExit any more)."""
    cfg = get_config("whisper-small", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(6, 4), (9, 6)]
    batches = [family_batch(cfg, 1, p, seed=10 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    eng = ServeEngine(params, cfg, n_slots=2, max_len=max_len, seg_len=3)
    for b, (_, g) in zip(batches, lengths):
        eng.submit(b, max_new=g)
    comps = eng.run()
    assert sorted(comps) == [0, 1]
    for uid, (_, g) in enumerate(lengths):
        assert len(comps[uid].tokens) == g
        solo = _solo_tokens(params, cfg, batches[uid], g, max_len, uid,
                            jax.random.PRNGKey(0))
        assert comps[uid].tokens.tolist() == solo


def test_engine_rejects_oversized_request():
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(params, cfg, n_slots=1, max_len=16)
    batch = {"tokens": jnp.zeros((1, 12), jnp.int32)}
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(batch, max_new=8)


# ---------------------------------------------------------------------------
# bug-sweep regressions (ISSUE 4 satellites)
# ---------------------------------------------------------------------------

def test_zero_temperature_samplers_decode_greedily():
    """t=0 (or tiny t) used to divide f32 logits by max(t, 1e-6); now it
    dispatches to argmax and never produces non-finite probabilities."""
    from repro.serve import TopK
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    logits = jax.random.normal(jax.random.PRNGKey(1), (3, 64)) * 1e4
    greedy = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
    for sampler in (Temperature(0.0), Temperature(1e-6), TopK(8, 0.0)):
        out = np.asarray(sampler(keys, logits))
        np.testing.assert_array_equal(out, greedy)


def test_topk_clamps_k_to_vocab():
    """k > V used to raise inside lax.top_k."""
    from repro.serve import TopK
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    logits = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    out = np.asarray(TopK(k=1000, t=1.0)(keys, logits))
    assert out.shape == (2,) and (0 <= out).all() and (out < 16).all()
    # k=V*10 at t->0 still equals argmax
    np.testing.assert_array_equal(
        np.asarray(TopK(k=1000, t=0.0)(keys, logits)),
        np.asarray(jnp.argmax(logits, -1).astype(jnp.int32)))


def test_engine_host_state_is_bounded():
    """completions drain via pop_completions, history is a bounded deque,
    and the per-prompt-length compile caches evict old executables."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(params, cfg, n_slots=2, max_len=24, seg_len=2,
                      history_limit=3, compile_cache_size=2)
    lengths = [(5, 3), (6, 3), (7, 3), (8, 3)]  # 4 distinct prompt shapes
    for p, g in lengths:
        eng.submit({"tokens": jnp.zeros((1, p), jnp.int32)}, max_new=g)
    comps = eng.run()
    assert sorted(comps) == [0, 1, 2, 3]
    # compile caches: at most 2 per-length executables pinned
    assert len(eng._prefill_exec) <= 2 and len(eng._admit_exec) <= 2
    # history bounded
    assert len(eng.history) <= 3
    # drain: uids become reusable afterwards
    popped = eng.pop_completions()
    assert sorted(popped) == [0, 1, 2, 3] and not eng.completions
    assert not eng._out and not eng._plen and not eng._nseg
    eng.submit({"tokens": jnp.zeros((1, 5), jnp.int32)}, max_new=2, uid=0)
    assert eng.run()[0].tokens.shape == (2,)


def test_engine_uid_reuse_check_is_set_based():
    """uid reuse detection must not scan the queue (O(1) via the pending
    set) and must still catch duplicates in queue/live/completed."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(params, cfg, n_slots=1, max_len=16)
    batch = {"tokens": jnp.zeros((1, 4), jnp.int32)}
    for i in range(20):
        eng.submit(batch, max_new=2, uid=i)
    assert eng._pending == set(range(20))
    with pytest.raises(ValueError, match="already in use"):
        eng.submit(batch, max_new=2, uid=7)
    comps = eng.run()
    assert not eng._pending and sorted(comps) == list(range(20))
    with pytest.raises(ValueError, match="already in use"):
        eng.submit(batch, max_new=2, uid=7)  # now completed, still caught
