"""DeepFusion core invariants: clustering, proxies, VAA, merge, tuning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering, merge, proxy, tuning
from repro.core import vaa as vaa_mod
from repro.core.distill import select_stages
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update
from repro.utils.pytree import tree_average

SMALL = dict(vocab_size=128, dtype="float32", remat=False,
             attn_chunk_q=16, attn_chunk_k=16, loss_chunk=16)


def dense_cfg(**kw):
    base = dict(name="d", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                head_dim=16, d_ff=64, **SMALL)
    base.update(kw)
    return ModelConfig(**base).validate()


def moe_cfg(**kw):
    base = dict(name="m", arch_type="moe", n_layers=2, d_model=32, n_heads=2,
                n_kv_heads=2, head_dim=16, d_ff=64, n_experts=3, top_k=2,
                moe_d_ff=64, n_shared_experts=1, **SMALL)
    base.update(kw)
    return ModelConfig(**base).validate()


# ---------------------------------------------------------------------------
# Phase I
# ---------------------------------------------------------------------------

def test_similarity_matrix_is_cosine():
    e = np.random.default_rng(0).standard_normal((5, 8)).astype(np.float32)
    sim = clustering.cosine_similarity_matrix(e)
    assert sim.shape == (5, 5)
    np.testing.assert_allclose(np.diag(sim), 1.0, rtol=1e-5)
    assert np.all(sim <= 1.0 + 1e-6)


def test_kmeans_recovers_separated_clusters():
    rng = np.random.default_rng(0)
    centers = np.eye(4, 16, dtype=np.float32)
    e = np.concatenate([centers[i] + 0.01 * rng.standard_normal((10, 16))
                        for i in range(4)]).astype(np.float32)
    labels, _ = clustering.spherical_kmeans(e, 4, seed=0)
    for i in range(4):
        grp = labels[i * 10:(i + 1) * 10]
        assert len(set(grp.tolist())) == 1  # pure clusters
    assert len(set(labels.tolist())) == 4


def test_arch_constrained_clustering():
    rng = np.random.default_rng(1)
    e = rng.standard_normal((12, 8)).astype(np.float32)
    arch = [0, 1] * 6
    res = clustering.cluster_devices(e, 4, arch_ids=arch, seed=0)
    for members in res.members:
        archs = {arch[m] for m in members}
        assert len(archs) <= 1


def test_proxy_is_weight_average():
    cfg = dense_cfg()
    p1 = M.init_params(jax.random.PRNGKey(0), cfg)
    p2 = M.init_params(jax.random.PRNGKey(1), cfg)
    res = clustering.ClusterResult(
        labels=np.array([0, 0]), centroids=np.zeros((1, 4)),
        similarity=np.ones((2, 2)), members=[[0, 1]])
    proxies = proxy.build_proxies([p1, p2], res, [0, 0])
    assert len(proxies) == 1
    avg = tree_average([p1, p2])
    for a, b in zip(jax.tree.leaves(proxies[0]["params"]),
                    jax.tree.leaves(avg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_proxy_rejects_mixed_arch_cluster():
    cfg = dense_cfg()
    p1 = M.init_params(jax.random.PRNGKey(0), cfg)
    res = clustering.ClusterResult(
        labels=np.array([0, 0]), centroids=np.zeros((1, 4)),
        similarity=np.ones((2, 2)), members=[[0, 1]])
    with pytest.raises(AssertionError):
        proxy.build_proxies([p1, p1], res, [0, 1])


# ---------------------------------------------------------------------------
# Phase II: VAA
# ---------------------------------------------------------------------------

def test_vaa_shapes_and_grads():
    J, B, S, dS, dT, d, pq = 3, 2, 24, 32, 48, 16, 12
    key = jax.random.PRNGKey(0)
    params = vaa_mod.init_vaa(key, n_stages=J, d_student=dS, d_teacher=dT,
                              d=d, n_heads=2, p_q=pq)
    stages = [jax.random.normal(jax.random.PRNGKey(i), (B, S, dS))
              for i in range(J)]
    outs = vaa_mod.vaa_apply(params, stages, n_heads=2, p_q=pq)
    assert len(outs) == J
    for o in outs:
        assert o.shape == (B, pq // J, dT)
    t_stages = [jax.random.normal(jax.random.PRNGKey(10 + i), (B, S, dT))
                for i in range(J)]
    loss = vaa_mod.feature_matching_loss(params, stages, t_stages,
                                         n_heads=2, p_q=pq)
    assert jnp.isfinite(loss) and loss >= 0
    g = jax.grad(lambda p: vaa_mod.feature_matching_loss(
        p, stages, t_stages, n_heads=2, p_q=pq))(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_vaa_attention_mixes_stages():
    """Blended stage j must depend on OTHER stages' features (the view
    alignment property) — zeroing stage 0 changes stage 2's output."""
    J, B, S, dS, dT, pq = 3, 1, 8, 16, 16, 6
    params = vaa_mod.init_vaa(jax.random.PRNGKey(0), n_stages=J,
                              d_student=dS, d_teacher=dT, d=8, n_heads=2,
                              p_q=pq)
    stages = [jax.random.normal(jax.random.PRNGKey(i), (B, S, dS))
              for i in range(J)]
    out_a = vaa_mod.vaa_apply(params, stages, n_heads=2, p_q=pq)
    stages_b = [jnp.zeros_like(stages[0])] + stages[1:]
    out_b = vaa_mod.vaa_apply(params, stages_b, n_heads=2, p_q=pq)
    assert float(jnp.max(jnp.abs(out_a[2] - out_b[2]))) > 1e-6


def test_vaa_short_sequence_pads_to_full_patches():
    """Regression: S < P_q/J used to yield min(P, S) patches, misaligning
    the per-stage slices of the concatenated query block and breaking
    L_FM shapes.  patchify must always return exactly P patches."""
    J, B, S, dS, dT, pq = 4, 2, 8, 16, 24, 64
    P = pq // J  # 16 > S
    params = vaa_mod.init_vaa(jax.random.PRNGKey(0), n_stages=J,
                              d_student=dS, d_teacher=dT, d=16, n_heads=2,
                              p_q=pq)
    stages = [jax.random.normal(jax.random.PRNGKey(i), (B, S, dS))
              for i in range(J)]
    assert vaa_mod.patchify(stages[0], P).shape == (B, P, dS)
    outs = vaa_mod.vaa_apply(params, stages, n_heads=2, p_q=pq)
    assert len(outs) == J
    for o in outs:
        assert o.shape == (B, P, dT)
        assert bool(jnp.all(jnp.isfinite(o)))
    t_stages = [jax.random.normal(jax.random.PRNGKey(10 + i), (B, S, dT))
                for i in range(J)]
    loss = vaa_mod.feature_matching_loss(params, stages, t_stages,
                                         n_heads=2, p_q=pq)
    assert jnp.isfinite(loss) and loss >= 0
    g = jax.grad(lambda p: vaa_mod.feature_matching_loss(
        p, stages, t_stages, n_heads=2, p_q=pq))(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_patchify_preserves_mean():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 8))
    p = vaa_mod.patchify(x, 4)
    assert p.shape == (2, 4, 8)
    np.testing.assert_allclose(np.asarray(p[:, 0]),
                               np.asarray(x[:, :4].mean(1)), rtol=1e-5)


def test_select_stages_even_spacing():
    stages = jnp.arange(10)[:, None, None, None] * jnp.ones((10, 1, 2, 3))
    sel = select_stages(stages, 4)
    assert len(sel) == 4
    assert float(sel[-1][0, 0, 0]) == 9.0  # last stage always included


# ---------------------------------------------------------------------------
# Phase III: merge + tuning
# ---------------------------------------------------------------------------

def test_merge_rule_expert_copy_and_average():
    mcfg = moe_cfg()
    bcfg = merge.base_config_of(mcfg)
    assert bcfg.d_ff == mcfg.moe_d_ff
    bases = [M.init_params(jax.random.PRNGKey(i), bcfg) for i in range(3)]
    moe_params = merge.merge_into_moe(jax.random.PRNGKey(9), mcfg, bases)
    # Eq. 12: expert e FFN == base e FFN
    for e in range(3):
        np.testing.assert_allclose(
            np.asarray(moe_params["blocks"]["sub0"]["moe"]["wi_gate"][:, e]),
            np.asarray(bases[e]["blocks"]["sub0"]["mlp"]["wi_gate"]),
            rtol=1e-6)
    # Eq. 13: embedding == average of base embeddings
    avg_embed = sum(np.asarray(b["embed"], np.float64) for b in bases) / 3
    np.testing.assert_allclose(np.asarray(moe_params["embed"]), avg_embed,
                               rtol=1e-5, atol=1e-6)
    # attention weights averaged
    avg_wq = sum(np.asarray(b["blocks"]["sub0"]["attn"]["wq"], np.float64)
                 for b in bases) / 3
    np.testing.assert_allclose(
        np.asarray(moe_params["blocks"]["sub0"]["attn"]["wq"]), avg_wq,
        rtol=1e-5, atol=1e-6)
    # merged model must run
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                              mcfg.vocab_size)
    loss, _ = M.loss_fn(moe_params, mcfg,
                        {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(loss))


def test_merge_round_robin_when_fewer_bases():
    mcfg = moe_cfg()
    bcfg = merge.base_config_of(mcfg)
    bases = [M.init_params(jax.random.PRNGKey(i), bcfg) for i in range(2)]
    moe_params = merge.merge_into_moe(jax.random.PRNGKey(9), mcfg, bases)
    np.testing.assert_allclose(  # expert 2 <- base 0 (round robin)
        np.asarray(moe_params["blocks"]["sub0"]["moe"]["wo"][:, 2]),
        np.asarray(bases[0]["blocks"]["sub0"]["mlp"]["wo"]), rtol=1e-6)


def test_freeze_mask_freezes_experts_only():
    mcfg = moe_cfg()
    params = M.init_params(jax.random.PRNGKey(0), mcfg)
    mask = tuning.expert_freeze_mask(params)
    flat, _ = jax.tree_util.tree_flatten_with_path(mask)
    from repro.utils.pytree import path_str
    for pth, m in flat:
        p = path_str(pth)
        if "moe/wi_gate" in p or "moe/wi_up" in p or "moe/wo" in p \
           or "moe/shared/" in p:
            assert m is False, p
        else:
            assert m is True, p
    frac = tuning.trainable_fraction(params)
    assert 0 < frac < 1


def test_frozen_experts_unchanged_by_tuning_step():
    mcfg = moe_cfg()
    params = M.init_params(jax.random.PRNGKey(0), mcfg)
    mask, opt = tuning.init_tuning(params)
    step = tuning.make_tune_step(mcfg, mask)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              mcfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    new_params, opt, loss, _ = step(params, opt, batch, 1e-2)
    before = params["blocks"]["sub0"]["moe"]["wi_gate"]
    after = new_params["blocks"]["sub0"]["moe"]["wi_gate"]
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    # but the router DID move
    assert float(jnp.max(jnp.abs(
        params["blocks"]["sub0"]["moe"]["router"]
        - new_params["blocks"]["sub0"]["moe"]["router"]))) > 0
    # and frozen moments are scalar (memory claim of §IV.D)
    assert opt["m"]["blocks"]["sub0"]["moe"]["wi_gate"].shape == ()
