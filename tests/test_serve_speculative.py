"""Self-speculative MTP decode tests (ISSUE 8).

Covers:
  * greedy bit-identity: ``generate(speculate=k)`` emits exactly the
    token-by-token greedy stream — contiguous and paged caches, and the
    three engines (contiguous, paged, paged+bucketed);
  * partial-accept cache-state equivalence: after speculative steps the
    cache is bit-identical to the token-by-token cache — accepted
    positions carry the same k/v, rejected-draft positions are scrubbed
    (contiguous: zeroed in place; paged: zeroed in the slot's blocks,
    kept positions diverted to the trash block);
  * temperature verify: the residual rejection sampler's emitted
    marginal equals the target softmax regardless of draft quality;
  * acceptance-length properties: every live step emits at least 1 and
    at most k+1 tokens, the first lane of a live step is always valid,
    and emission stops permanently once a slot finishes;
  * (>= 8 devices) speculative + sharded + paged composition matches
    the single-device non-speculative engine token-for-token;
  * engines reject ``speculate`` without an MTP head.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, ServeEngine
from repro.serve.sampling import Greedy, Temperature, TopK, _residual_verify

from test_serve_chunked import family_batch, run_engine

MULTI = len(jax.devices()) >= 8
needs_multi = pytest.mark.skipif(
    not MULTI, reason="needs >= 8 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# MTP-capable families: deepseek-v3 ships n_mtp=1 natively (MLA + MoE),
# the others opt in via replace (dense GQA exercising the C>1 Pallas
# kernel, and GQA MoE routing under the verify chunk's live mask)
SPEC_CASES = [
    ("deepseek-v3-671b", {}),
    ("tinyllama-1.1b", {"n_mtp": 1, "use_pallas": True}),
    ("qwen2-moe-a2.7b", {"n_mtp": 1}),
]


def _spec_cfg(arch, over):
    return get_config(arch, variant="reduced").replace(**over)


def _model_setup(cfg, B=2, P=6, max_new=24, seed=0):
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, P), 0,
                              cfg.vocab_size)
    logits, pc = M.prefill(params, cfg, {"tokens": toks})
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    pos0 = jnp.full((B,), M.decode_pos0(cfg, P), jnp.int32)
    rng = jax.random.split(jax.random.PRNGKey(seed + 2), B)
    return params, pc, tok0, pos0, rng, P


def _emitted(res):
    t, v = np.asarray(res["tokens"]), np.asarray(res["valid"])
    return [t[b][v[b]].tolist() for b in range(t.shape[0])]


def _assert_scrubbed_contiguous(cfg, cache, fpos):
    """Every contiguous-cache row past a slot's frontier must be exactly
    zero: rejected-draft writes are scrubbed, not just masked.  (The
    frontier row itself holds the parked pending-token write, like the
    plain scan's.)"""
    bat = M.decode_cache_batch_axes(cfg)
    seq = M.decode_cache_seq_axes(cfg)
    for leaf, bax, sax in zip(jax.tree.leaves(cache), jax.tree.leaves(bat),
                              jax.tree.leaves(seq)):
        if sax < 0:
            continue
        sax2 = sax if sax > bax else sax + 1
        l = np.moveaxis(np.moveaxis(np.asarray(leaf, np.float32), bax, 0),
                        sax2, 1)
        for b, p in enumerate(fpos):
            assert not l[b, p + 1:].any()


# ---------------------------------------------------------------------------
# model layer: greedy bit-identity + cache-state equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,over", SPEC_CASES)
@pytest.mark.parametrize("k", [1, 3])
def test_spec_matches_ref_contiguous(arch, over, k):
    cfg = _spec_cfg(arch, over)
    params, pc, tok0, pos0, rng, P = _model_setup(cfg)
    rem = jnp.full((2,), 15, jnp.int32)
    cap = M.decode_capacity(cfg, P, 24)

    def fresh():
        c = M.init_decode_cache(cfg, 2, cap)
        return M.prefill_into_cache(cfg, c, pc)

    ref = M.generate(params, cfg, fresh(), tok0, pos0, steps=18,
                     rng=rng, remaining=rem)
    spec = M.generate(params, cfg, fresh(), tok0, pos0, steps=18,
                      rng=rng, remaining=rem, speculate=k)
    assert _emitted(spec) == _emitted(ref)
    # partial-accept equivalence: accepted positions carry the same kv
    # as the token-by-token cache (to float tolerance — the C-wide
    # verify chunk reduces attention in a different shape than the C=1
    # step) and everything past the frontier is scrubbed to EXACT zeros,
    # matching the untouched rows of the token-by-token cache
    for a, b in zip(jax.tree.leaves(spec["cache"]),
                    jax.tree.leaves(ref["cache"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)
    _assert_scrubbed_contiguous(cfg, spec["cache"], np.asarray(spec["pos"]))


@pytest.mark.parametrize("arch,over", SPEC_CASES)
def test_spec_matches_ref_paged(arch, over):
    cfg = _spec_cfg(arch, over)
    params, pc, tok0, pos0, rng, P = _model_setup(cfg)
    B, bl, W, k = 2, 4, 12, 3
    rem = jnp.full((B,), 15, jnp.int32)
    n_pb = -(-M.decode_pos0(cfg, P) // bl)
    tables = np.stack([np.arange(1 + W * b, 1 + W * (b + 1), dtype=np.int32)
                       for b in range(B)])
    sub = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, B, n_pb * bl), pc)
    bat = M.decode_cache_batch_axes(cfg)

    def fresh():
        c = M.init_paged_cache(cfg, B, 1 + B * W, bl)
        for b in range(B):
            sub_b = jax.tree.map(
                lambda x, ax: jax.lax.index_in_dim(x, b, ax, keepdims=True),
                sub, bat)
            c = M.scatter_prefill_paged(
                cfg, c, sub_b, b, jnp.asarray(tables[b][:n_pb]),
                jnp.ones((n_pb,), jnp.bool_), block_len=bl)
        return c

    bt = jnp.asarray(tables)
    ref = M.generate(params, cfg, fresh(), tok0, pos0, steps=18, rng=rng,
                     remaining=rem, block_tables=bt)
    spec = M.generate(params, cfg, fresh(), tok0, pos0, steps=18, rng=rng,
                      remaining=rem, block_tables=bt, speculate=k)
    assert _emitted(spec) == _emitted(ref)
    # pool equivalence outside the trash block: accepted writes match the
    # token-by-token stream, rejected writes in the slots' own blocks are
    # zeroed (kept positions divert their zero-write to trash block 0,
    # which is scratch by contract and excluded here)
    def nontrash(leaf):
        if leaf.ndim and leaf.shape[0] == 1 + B * W:  # pool leaf
            return np.asarray(leaf, np.float32)[1:]
        return np.asarray(leaf, np.float32)

    # pool equivalence outside the trash block (scratch by contract):
    # accepted writes match the token-by-token stream to float tolerance,
    # and each slot's blocks past the frontier hold EXACT zeros — the
    # rejected-draft writes were scrubbed via trash-diverted zero-writes
    for a, b in zip(jax.tree.leaves(spec["cache"]),
                    jax.tree.leaves(ref["cache"])):
        np.testing.assert_allclose(nontrash(a), nontrash(b),
                                   atol=1e-4, rtol=1e-3)
    fpos = np.asarray(spec["pos"])
    for leaf in jax.tree.leaves(spec["cache"]):
        if not (leaf.ndim and leaf.shape[0] == 1 + B * W):
            continue
        pool = np.asarray(leaf, np.float32)
        for b in range(B):
            flat = pool[tables[b]].reshape((W * bl,) + pool.shape[2:])
            assert not flat[fpos[b] + 1:].any()


@pytest.mark.parametrize("k", [2, 4])
def test_acceptance_length_properties(k):
    cfg = _spec_cfg(*SPEC_CASES[0])
    params, pc, tok0, pos0, rng, P = _model_setup(cfg)
    rem = jnp.asarray([21, 7], jnp.int32)  # second slot finishes early
    cache = M.prefill_into_cache(
        cfg, M.init_decode_cache(cfg, 2, M.decode_capacity(cfg, P, 24)), pc)
    res = M.generate(params, cfg, cache, tok0, pos0, steps=12, rng=rng,
                     remaining=rem, speculate=k)
    valid = np.asarray(res["valid"])
    C = k + 1
    for b in range(2):
        per_step = valid[b].reshape(-1, C)
        alive = per_step.sum(1) > 0
        # a live step emits >= 1 (verified resample is unconditional) and
        # <= k+1; its first lane is always the emission that is never
        # rolled back
        assert all(per_step[alive, 0])
        assert per_step.sum(1).max() <= C
        # once dead, dead forever
        first_dead = np.argmin(alive) if not alive.all() else len(alive)
        assert not per_step[first_dead:].any()
        # no eos here, so the only stop is the emission budget: a slot
        # that died inside the scan spent exactly `remaining`; one still
        # alive at the end must not have overdrawn it
        if not alive.all():
            assert valid[b].sum() == int(rem[b])
        else:
            assert valid[b].sum() < int(rem[b])


# ---------------------------------------------------------------------------
# temperature verify: residual rejection sampling is exact
# ---------------------------------------------------------------------------

def test_residual_verify_matches_target_distribution():
    V, N, t = 6, 20000, 0.8
    logits = jnp.asarray([1.2, -0.3, 0.7, 2.0, -1.0, 0.1])
    target = np.asarray(jax.nn.softmax(logits / t))
    keys = jax.random.split(jax.random.PRNGKey(0), N)
    for d in (3, 4):  # a good draft (modal) and a bad one (rare token)
        draft = jnp.full((N,), d, jnp.int32)
        toks, acc = _residual_verify(keys,
                                     jnp.broadcast_to(logits, (N, V)),
                                     draft, t)
        toks = np.asarray(toks)
        emp = np.bincount(toks, minlength=V) / N
        # emitted marginal == target regardless of the draft
        np.testing.assert_allclose(emp, target, atol=0.02)
        # acceptance rate == target prob of the drafted token
        np.testing.assert_allclose(np.asarray(acc).mean(), target[d],
                                   atol=0.02)
        # rejections never emit the draft
        assert not np.any(toks[~np.asarray(acc)] == d)


def test_verify_methods_greedy_limits():
    """t -> 0 verify degenerates to exact argmax prefix matching."""
    logits = jnp.asarray([[0.1, 2.0, -1.0], [3.0, 0.0, 0.2]])
    keys = jax.random.split(jax.random.PRNGKey(1), 2)
    draft = jnp.asarray([1, 1], jnp.int32)
    for s in (Greedy(), Temperature(0.0), TopK(2, 0.0)):
        tok, acc = s.verify(keys, logits, draft)
        np.testing.assert_array_equal(np.asarray(tok), [1, 0])
        np.testing.assert_array_equal(np.asarray(acc), [True, False])


# ---------------------------------------------------------------------------
# engines: speculative == plain, all layouts
# ---------------------------------------------------------------------------

TRAFFIC = [(6, 8), (9, 12), (7, 10), (11, 6)]


def _engine_traffic(cfg):
    batches = [family_batch(cfg, p, seed=10 + i)
               for i, (p, _) in enumerate(TRAFFIC)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in TRAFFIC)
    return batches, max_len


@pytest.mark.parametrize("arch,over", SPEC_CASES)
def test_spec_engines_match_plain(arch, over):
    cfg = _spec_cfg(arch, over)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batches, max_len = _engine_traffic(cfg)
    ref, _ = run_engine(ServeEngine, params, cfg, batches, TRAFFIC, max_len,
                        n_slots=2, seg_len=3, seed=0)
    spec, e1 = run_engine(ServeEngine, params, cfg, batches, TRAFFIC,
                          max_len, n_slots=2, seg_len=3, seed=0, speculate=3)
    paged, e2 = run_engine(PagedServeEngine, params, cfg, batches, TRAFFIC,
                           max_len, n_slots=2, seg_len=3, seed=0,
                           block_len=4, speculate=3)
    buck, e3 = run_engine(PagedServeEngine, params, cfg, batches, TRAFFIC,
                          max_len, n_slots=2, seg_len=3, seed=0, block_len=4,
                          chunk_len=4, speculate=3)
    assert spec == ref and paged == ref and buck == ref
    for e in (e1, e2, e3):
        assert e.stats["spec_steps"] > 0
        assert 0.0 <= e.spec_acceptance() <= 1.0


def test_spec_engine_full_capacity_overshoot():
    """A request generating to the exact cache capacity: the last verify
    chunks overshoot the final block — spare TRASH table columns must
    absorb them (a clamped gather would alias the last real block)."""
    cfg = _spec_cfg(*SPEC_CASES[0])
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    P, G = 6, 10
    max_len = M.decode_capacity(cfg, P, G)
    batches = [family_batch(cfg, P, seed=3)]
    ref, _ = run_engine(ServeEngine, params, cfg, batches, [(P, G)], max_len,
                        n_slots=2, seg_len=3, seed=0)
    # block_len 4 with speculate 6 forces _spec_spare > 1
    spec, eng = run_engine(PagedServeEngine, params, cfg, batches, [(P, G)],
                           max_len, n_slots=2, seg_len=3, seed=0,
                           block_len=4, speculate=6)
    assert spec == ref
    assert eng._spec_spare == 2
    assert eng.block_tables.shape[1] == eng.max_blocks + 2


def test_spec_requires_mtp_head():
    cfg = get_config("tinyllama-1.1b", variant="reduced")  # n_mtp = 0
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    with pytest.raises(ValueError, match="MTP"):
        ServeEngine(params, cfg, max_len=32, speculate=3)


def test_mtp_chain_loss_depth1_matches_mtp_loss():
    """Chained MTP training loss at depth 1 IS the stock ``_mtp_loss``
    (same norm/proj/block wiring, same roll-and-mask bookkeeping)."""
    cfg = _spec_cfg(*SPEC_CASES[0])
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    h, _, _, _ = M.backbone(params, cfg, batch)
    ref = M._mtp_loss(params, cfg, h, batch)
    got = M.mtp_chain_loss(params, cfg, batch, depth=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    # deeper chains add terms; still a finite scalar
    deep = M.mtp_chain_loss(params, cfg, batch, depth=3)
    assert np.isfinite(np.asarray(deep))


def test_spec_admission_seeds_draft_hidden():
    """Unbucketed admission warm-starts ``h_spec`` from the prefill's
    last hidden (the position that emitted the first token) — the first
    speculative step drafts hot instead of burning its lanes on a zero
    seed.  Chunked admission stays cold."""
    cfg = _spec_cfg(*SPEC_CASES[0])
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    P = 6
    eng = ServeEngine(params, cfg, n_slots=2, max_len=32, seg_len=3,
                      speculate=3)
    eng.submit(family_batch(cfg, P, seed=0), max_new=8)
    eng._admit()
    assert np.abs(eng.h_spec[0]).sum() > 0
    (_, h0), _ = M.prefill(params, cfg, family_batch(cfg, P, seed=0),
                           return_hidden=True)
    np.testing.assert_array_equal(eng.h_spec[0], np.asarray(h0[0]))
    cold = ServeEngine(params, cfg, n_slots=2, max_len=64, seg_len=3,
                       speculate=3, chunk_len=4)
    cold.submit(family_batch(cfg, P, seed=0), max_new=8)
    cold._admit()
    assert not np.abs(cold.h_spec[0]).sum()


@needs_multi
@pytest.mark.parametrize("arch,over", [SPEC_CASES[0], SPEC_CASES[2]])
def test_spec_sharded_matches_single_device(arch, over):
    """speculate + paged + 8-way mesh vs the plain single-device engine:
    token-identical completions (MoE runs dropless, liveness-masked)."""
    from repro.launch.mesh import make_decode_mesh
    cfg = _spec_cfg(arch, over)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batches, max_len = _engine_traffic(cfg)
    ref, _ = run_engine(ServeEngine, params, cfg, batches, TRAFFIC, max_len,
                        n_slots=2, seg_len=3, seed=0)
    mesh = make_decode_mesh(8)
    sh, _ = run_engine(ServeEngine, params, cfg, batches, TRAFFIC, max_len,
                       n_slots=2, seg_len=3, seed=0, mesh=mesh, speculate=3)
    psh, _ = run_engine(PagedServeEngine, params, cfg, batches, TRAFFIC,
                        max_len, n_slots=2, seg_len=3, seed=0, mesh=mesh,
                        block_len=4, speculate=3)
    assert sh == ref and psh == ref
