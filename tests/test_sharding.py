"""Sharding-rule unit tests (mesh-abstract; real lowering in the dry-run).

Uses jax.sharding.Mesh over a fake 16x16 device grid built from the host
device replicated via AbstractMesh where possible; spec construction and
divisibility logic are pure functions of shapes, so no devices needed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import model as M
from repro.sharding import rules

MESH = rules.abstract_mesh((16, 16), ("data", "model"))
MESH3 = rules.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _abstract_params(name):
    cfg = get_config(name)
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def _check_divisible(params, specs, mesh):
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert leaf.shape[dim] % n == 0, (leaf.shape, spec, dim)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "deepseek-v3-671b", "mamba2-1.3b",
                                  "zamba2-7b", "gemma2-27b", "whisper-small",
                                  "paligemma-3b", "starcoder2-3b",
                                  "gemma2-9b"])
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["16x16", "2x16x16"])
def test_param_specs_divisible_for_all_archs(arch, mesh):
    params = _abstract_params(arch)
    specs = rules.param_specs(params, mesh, fsdp=True)
    _check_divisible(params, specs, mesh)


def test_expert_dim_fallback_for_non_divisible_experts():
    """Qwen's 60 experts can't shard on the 16-way model axis; the rule
    must fall back to sharding the expert FFN hidden dim."""
    params = _abstract_params("qwen2-moe-a2.7b")
    specs = rules.param_specs(params, MESH, fsdp=False)
    spec = specs["blocks"]["sub0"]["moe"]["wi_gate"]
    assert spec[1] is None            # expert dim (60) unsharded
    assert "model" in tuple(spec)     # but model parallelism retained


def test_expert_dim_sharded_when_divisible():
    params = _abstract_params("deepseek-v3-671b")
    specs = rules.param_specs(params, MESH, fsdp=False)
    spec = specs["blocks"]["sub0"]["moe"]["wi_gate"]
    assert spec[1] == "model"         # 256 experts / 16 OK


def test_fsdp_extends_over_data_axes():
    params = _abstract_params("tinyllama-1.1b")
    s_no = rules.param_specs(params, MESH, fsdp=False)
    s_yes = rules.param_specs(params, MESH, fsdp=True)
    # attention wq (L, D, H*Dh): fsdp adds "data" on the D dim
    wq_no = s_no["blocks"]["sub0"]["attn"]["wq"]
    wq_yes = s_yes["blocks"]["sub0"]["attn"]["wq"]
    assert "data" not in jax.tree.leaves(tuple(wq_no)) or True
    assert any(ax == "data" or (isinstance(ax, tuple) and "data" in ax)
               for ax in wq_yes if ax is not None)


def test_batch_spec_replicates_tiny_batches():
    batch = {"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32)}
    spec = rules.batch_spec(batch, MESH)
    assert spec["tokens"] == P(None, None)
    batch = {"tokens": jax.ShapeDtypeStruct((256, 16), jnp.int32)}
    spec = rules.batch_spec(batch, MESH)
    assert spec["tokens"] == P("data", None)


def test_cache_specs_decode_layouts():
    cfg = get_config("gemma2-9b")
    cache = jax.eval_shape(lambda: M.init_decode_cache(cfg, 128, 32768))
    specs = rules.cache_specs(cache, MESH, batch=128, seq=32768)
    k_spec = specs["blocks"]["sub0"]["k"]  # (nG, B, S, KH, Dh)
    assert k_spec[1] == "data"            # batch sharded
    assert k_spec[2] == "model"           # seq sharded over model
    # long_500k: B=1 -> sequence-parallel over ALL axes
    cache1 = jax.eval_shape(lambda: M.init_decode_cache(cfg, 1, 524288))
    specs1 = rules.cache_specs(cache1, MESH, batch=1, seq=524288)
    k1 = specs1["blocks"]["sub0"]["k"]
    assert k1[2] == ("data", "model")


def test_opt_state_specs_follow_params():
    params = _abstract_params("tinyllama-1.1b")
    o = rules.opt_state_specs(params, MESH)
    p = rules.param_specs(params, MESH)
    assert jax.tree.structure(o["m"], is_leaf=lambda s: isinstance(s, P)) \
        == jax.tree.structure(p, is_leaf=lambda s: isinstance(s, P))
