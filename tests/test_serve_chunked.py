"""Chunked prefill + prompt-length bucketing tests (ISSUE 5).

Covers:
  * per-family equivalence of ``prefill_chunked`` (prompt fed through
    the decode body in fixed chunks, bucket-padded) against one-shot
    ``prefill`` + graft: last-token logits agree to float tolerance,
    greedy decode continuations are token-identical, and the ssm/hybrid
    recurrent state carried across chunks matches (pads frozen out);
  * the bucketed engines (contiguous and paged) emit token-identical
    completions to the unbucketed engine while compiling O(#buckets)
    admission executables instead of O(#distinct prompt lengths);
  * paged lazy per-segment block claiming: admission holds only the
    prompt's blocks, decode blocks are claimed as the frontier crosses
    boundaries, prefix-shared preambles keep their refcounts straight,
    and pool exhaustion preempts the youngest request which replays
    deterministically;
  * bucket-ladder properties: NO ladder ever truncates a prompt.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, ServeEngine, Temperature
from repro.serve import bucketing as bk

# the six decode families of the ISSUE: plain attention, GQA with
# sliding window + softcaps, MLA latent, ssm, hybrid, encdec
CHUNK_FAMILY_ARCHS = [
    "tinyllama-1.1b",    # attention (stacked KV blocks)
    "gemma2-9b",         # GQA + local/global pattern + logit softcaps
    "deepseek-v3-671b",  # MLA latent cache + leading dense layers
    "mamba2-1.3b",       # ssm: recurrent state carried across chunks
    "zamba2-7b",         # hybrid: shared-attn KV + mamba state carry
    "whisper-small",     # encdec: encoder + cross KV once, chunked decoder
]
# engine equivalence adds the remaining cache layouts
ENGINE_ARCHS = CHUNK_FAMILY_ARCHS + [
    "qwen2-moe-a2.7b",   # moe routing under chunked admission
    "paligemma-3b",      # vlm: patch rows inside the chunked sequence
]


def family_batch(cfg, P, seed=3):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, P), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    dt = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["patches"] = (jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (1, cfg.frontend_tokens, cfg.d_model)) * 0.05).astype(dt)
    if cfg.arch_type == "encdec":
        batch["frames"] = (jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (1, cfg.frontend_tokens, cfg.d_model)) * 0.05).astype(dt)
    return batch


def pad_for_chunks(cfg, batch, chunk_len):
    """Right-pad tokens so offset + T is a chunk multiple (what the
    engine's ``_padded_batch`` does through the bucket ladder)."""
    off = M.decode_offset(cfg)
    P = batch["tokens"].shape[1]
    S_pad = -(-(off + P) // chunk_len) * chunk_len
    toks = jnp.zeros((1, S_pad - off), jnp.int32).at[:, :P].set(
        batch["tokens"])
    out = dict(batch)
    out["tokens"] = toks
    return out


@pytest.mark.parametrize("arch", CHUNK_FAMILY_ARCHS)
def test_prefill_chunked_matches_one_shot(arch):
    """P=9 is deliberately NOT a chunk multiple: the last chunk carries
    bucket padding, which must not leak into logits, KV or state."""
    cfg = get_config(arch, variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    P, G = 9, 5
    batch = family_batch(cfg, P)
    logits0, pc = M.prefill(params, cfg, batch)
    cap = M.decode_capacity(cfg, P, G + 1)
    pos0 = M.decode_pos0(cfg, P)
    ref_cache = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, 1, cap), pc)

    outs = {}
    for C in (2, 4):
        lg, cache = jax.jit(
            lambda p, c, b, C=C: M.prefill_chunked(p, cfg, c, b, P,
                                                   chunk_len=C)
        )(params, M.init_decode_cache(cfg, 1, cap),
          pad_for_chunks(cfg, batch, C))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits0),
                                   atol=2e-4, rtol=2e-4)
        assert int(jnp.argmax(lg, -1)[0]) == int(jnp.argmax(logits0, -1)[0])
        # recurrent leaves (ssm/hybrid state+conv) must match the one-shot
        # prefill: chunk boundaries and pad freezing change float order
        # only.  Attention leaves are checked via the decode continuation.
        seq = M.decode_cache_seq_axes(cfg)
        for rl, cl, ax in zip(jax.tree.leaves(ref_cache),
                              jax.tree.leaves(cache),
                              jax.tree.leaves(seq)):
            if ax < 0 and rl.size:
                np.testing.assert_allclose(
                    np.asarray(cl, np.float32), np.asarray(rl, np.float32),
                    atol=2e-2, rtol=2e-2)
        res = M.generate(params, cfg, cache, jnp.argmax(logits0, -1),
                         jnp.asarray([pos0]), steps=G)
        outs[C] = np.asarray(res["tokens"])[0].tolist()
    ref = M.generate(params, cfg, ref_cache, jnp.argmax(logits0, -1),
                     jnp.asarray([pos0]), steps=G)
    ref_toks = np.asarray(ref["tokens"])[0].tolist()
    assert outs[2] == ref_toks and outs[4] == ref_toks


def test_chunked_ssm_state_freezes_pads():
    """The SSD recurrence integrates every token it sees; bucket pads
    must contribute nothing to the carried state or the conv tail."""
    cfg = get_config("mamba2-1.3b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    P, C = 7, 4  # one pad position in the last chunk
    batch = family_batch(cfg, P)
    _, pc = M.prefill(params, cfg, batch)
    ref = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, 1, 16), pc)
    padded = pad_for_chunks(cfg, batch, C)
    # poison the pad token: if it leaked into state/conv, this changes it
    poisoned = dict(padded)
    poisoned["tokens"] = padded["tokens"].at[0, P:].set(cfg.vocab_size - 1)
    states = []
    for b in (padded, poisoned):
        _, cache = M.prefill_chunked(params, cfg,
                                     M.init_decode_cache(cfg, 1, 16), b, P,
                                     chunk_len=C)
        states.append(cache)
    a = jax.tree.leaves(states[0])
    bzt = jax.tree.leaves(states[1])
    for x, y in zip(a, bzt):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_allclose(
        np.asarray(states[0]["blocks"]["state"]),
        np.asarray(ref["blocks"]["state"]), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(states[0]["blocks"]["conv"], np.float32),
        np.asarray(ref["blocks"]["conv"], np.float32), atol=1e-3, rtol=1e-3)


def run_engine(cls, params, cfg, batches, lengths, max_len, **kw):
    eng = cls(params, cfg, max_len=max_len, **kw)
    for b, (_, g) in zip(batches, lengths):
        eng.submit(b, max_new=g)
    comps = eng.run()
    return {u: c.tokens.tolist() for u, c in comps.items()}, eng


@pytest.mark.parametrize("arch", ENGINE_ARCHS)
def test_bucketed_engine_matches_unbucketed(arch):
    """Contiguous + paged bucketed engines vs the unbucketed engine on
    mixed-length traffic: token-identical completions, O(#buckets)
    admission compiles."""
    cfg = get_config(arch, variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(6, 4), (9, 6), (7, 5), (11, 3)]  # 4 distinct prompt lengths
    batches = [family_batch(cfg, p, seed=10 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    ref, e0 = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                         n_slots=2, seg_len=3, seed=0)
    buck, e1 = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                          n_slots=2, seg_len=3, seed=0, chunk_len=4)
    paged, e2 = run_engine(PagedServeEngine, params, cfg, batches, lengths,
                           max_len, n_slots=2, seg_len=3, seed=0, chunk_len=4,
                           block_len=4)
    assert buck == ref and paged == ref
    # unbucketed: prefill + admit per distinct length; bucketed: one
    # chunked-admit executable per bucket rung actually used
    n_lengths = len({p for p, _ in lengths})
    assert e0.compiles_built == 2 * n_lengths
    assert e1.compiles_built <= len(e1.buckets)
    assert e2.compiles_built <= len(e2.buckets)
    assert e2.alloc.n_free == e2.alloc.n_blocks - 1  # fully drained


def test_bucketed_sampling_matches_unbucketed():
    """Stochastic sampling: the per-request key protocol is identical
    under chunked admission, so temperature outputs match too."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(6, 5), (9, 4), (5, 6)]
    batches = [family_batch(cfg, p, seed=30 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    kw = dict(n_slots=2, seg_len=3, seed=7, sampler=Temperature(0.8))
    ref, _ = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                        **kw)
    buck, _ = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                         chunk_len=4, **kw)
    assert buck == ref


def test_lazy_allocation_claims_blocks_per_segment():
    """Lazy admission holds prompt blocks only; eager (lazy=False) holds
    the worst case up front.  Same traffic, same outputs, lower peak."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(5, 16), (6, 16)]  # long max_new: big eager reservations
    batches = [family_batch(cfg, p, seed=40 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    kw = dict(n_slots=2, seg_len=2, seed=0, block_len=4)
    outs = {}
    peaks = {}
    for lazy in (False, True):
        outs[lazy], eng = run_engine(PagedServeEngine, params, cfg, batches,
                                     lengths, max_len, lazy=lazy, **kw)
        peaks[lazy] = eng.stats["peak_live_blocks"]
        assert eng.alloc.n_free == eng.alloc.n_blocks - 1
        assert not eng._slot_blocks
        if lazy:
            assert eng.stats["lazy_claimed_blocks"] > 0
    assert outs[True] == outs[False]
    # eager peak covers both requests' full capacity; lazy peaks at the
    # EOS-free frontier + one segment of lookahead
    assert peaks[True] < peaks[False]


def test_eager_blocks_with_chunked_admission():
    """lazy=False + chunk_len: the admission tables carry only the
    prompt blocks (the eager reservation can exceed the rung-wide
    table when max_new is long — this used to crash), outputs still
    match the unbucketed engine."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(5, 16), (6, 14)]  # capacity well past the prompt's rung
    batches = [family_batch(cfg, p, seed=60 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    ref, _ = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                        n_slots=2, seg_len=3, seed=0)
    eager, eng = run_engine(PagedServeEngine, params, cfg, batches, lengths,
                            max_len, n_slots=2, seg_len=3, seed=0,
                            block_len=4, chunk_len=4, lazy=False)
    assert eager == ref
    assert eng.stats["lazy_claimed_blocks"] == 0
    assert eng.alloc.n_free == eng.alloc.n_blocks - 1


def test_lazy_prefix_sharing_keeps_refcounts():
    """Shared-preamble traffic through the lazy chunked paged engine:
    preamble blocks pooled once, refcounts drain to zero, outputs match
    the contiguous engine."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    pre = rng.integers(0, cfg.vocab_size, (1, 8))  # 2 full blocks @ bl=4
    gens = [5, 7, 4, 6]
    batches, lengths = [], []
    for g in gens:
        sfx = rng.integers(0, cfg.vocab_size, (1, 4))
        batches.append({"tokens": jnp.asarray(
            np.concatenate([pre, sfx], 1), jnp.int32)})
        lengths.append((12, g))
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    ref, _ = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                        n_slots=4, seg_len=3, seed=0)
    paged, eng = run_engine(PagedServeEngine, params, cfg, batches, lengths,
                            max_len, n_slots=4, seg_len=3, seed=0,
                            block_len=4, chunk_len=4)
    assert paged == ref
    assert eng.stats["shared_blocks"] > 0
    assert eng.alloc.n_free == eng.alloc.n_blocks - 1
    assert not eng.alloc._bid_of and not eng.alloc._key_of
    assert all(r == 0 for r in eng.alloc.refcount)


def test_preemption_replays_identically():
    """A pool too small for three long-running lazy requests forces
    preemption; the preempted request replays deterministically, so the
    completions still match the contiguous engine."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(8, 12), (8, 12), (8, 12)]
    batches = [family_batch(cfg, p, seed=20 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    ref, _ = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                        n_slots=3, seg_len=4, seed=0)
    # 10 allocatable blocks < 3 * ceil(20/4): someone must be preempted
    pre, eng = run_engine(PagedServeEngine, params, cfg, batches, lengths,
                          max_len, n_slots=3, seg_len=4, seed=0, block_len=4,
                          n_blocks=11, chunk_len=4)
    assert pre == ref
    assert eng.stats["preemptions"] > 0
    assert eng.alloc.n_free == eng.alloc.n_blocks - 1


def test_engine_compile_count_is_bucket_bounded():
    """12 distinct prompt lengths: the unbucketed engine builds 2 per
    length, the bucketed engine at most one per ladder rung."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(p, 2) for p in range(4, 16)]
    batches = [family_batch(cfg, p, seed=50 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    ref, e0 = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                         n_slots=2, seg_len=2, seed=0,
                         compile_cache_size=64)
    buck, e1 = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                          n_slots=2, seg_len=2, seed=0, chunk_len=4)
    assert buck == ref
    assert e0.compiles_built == 2 * len(lengths)
    assert e1.compiles_built <= len(e1.buckets)
    assert e1.compiles_built < e0.compiles_built


# ---------------------------------------------------------------------------
# bucket-ladder properties
# ---------------------------------------------------------------------------

def test_bucket_ladder_never_truncates():
    """Property: for EVERY ladder and prompt length, the chosen bucket
    is >= the length (no truncation) and a chunk multiple."""
    for chunk in (1, 2, 3, 4, 8):
        for max_len in (1, 7, 16, 100):
            ladder = bk.bucket_ladder(chunk, max_len)
            assert ladder[-1] >= max_len
            for S in range(0, 2 * max_len + 1):
                b = bk.bucket_for(S, ladder, chunk)
                assert b >= S, (chunk, max_len, S, b)
                assert b % chunk == 0
    # custom (sparse, user-supplied) ladders: lengths past the top rung
    # extend by chunk multiples instead of truncating
    ladder = bk.validate_ladder([8, 32], 4)
    for S in range(0, 100):
        b = bk.bucket_for(S, ladder, 4)
        assert b >= S and b % 4 == 0


def test_bucket_ladder_validation():
    with pytest.raises(ValueError, match="multiple"):
        bk.validate_ladder([6], 4)
    with pytest.raises(ValueError, match="empty"):
        bk.validate_ladder([], 4)
    with pytest.raises(ValueError, match="chunk_len"):
        ServeEngine(None, get_config("tinyllama-1.1b", variant="reduced"),
                    buckets=[8])


def test_bucketed_engine_rejects_oversized_request():
    """Capacity validation is bucket-independent: a prompt that fits no
    cache row is rejected at submit, never silently truncated."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(params, cfg, n_slots=1, max_len=16, chunk_len=4)
    with pytest.raises(ValueError, match="capacity"):
        eng.submit({"tokens": jnp.zeros((1, 12), jnp.int32)}, max_new=8)
