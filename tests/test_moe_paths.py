"""Sharded MoE execution paths (a2a / replicated_ep) on a forced
multi-device CPU backend.

XLA's host device count is locked at backend init, so this runs in a
subprocess with XLA_FLAGS set — the only way to exercise the shard_map
paths (and their shared dispatch/combine slot layout) under pytest.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.models import moe
from repro.models.config import ModelConfig

# E=3 exercises the expert-padding branch (E_pad=4 on the 2-way axis)
cfg0 = ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=16,
                   n_heads=2, n_kv_heads=2, head_dim=8, d_ff=32,
                   n_experts=3, top_k=2, moe_d_ff=24, vocab_size=64,
                   capacity_factor=2.0,  # dropless here: comparable to dense
                   dtype="float32").validate()
p = moe.init_moe(jax.random.PRNGKey(0), cfg0, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))

dense, _ = moe.apply_moe(p, cfg0.replace(moe_impl="dense"), x, mesh)
for impl in ("a2a", "replicated_ep"):
    c = cfg0.replace(moe_impl=impl, use_pallas=True)
    out_p, _ = moe.apply_moe(p, c, x, mesh)
    out_x, _ = moe.apply_moe(p, c.replace(use_pallas=False), x, mesh)
    d = float(jnp.abs(out_p - out_x).max())
    assert d < 1e-5, (impl, "pallas vs xla", d)
    # generous capacity -> no drops -> sharded path matches dense
    dd = float(jnp.abs(out_x - dense).max())
    assert dd < 1e-4, (impl, "vs dense", dd)

# gradients flow through the sharded pallas path (the headline bugfix)
c = cfg0.replace(moe_impl="replicated_ep", use_pallas=True)
g = jax.grad(lambda p: jnp.sum(moe.apply_moe(p, c, x, mesh)[0] ** 2))(p)
for name in ("wi_gate", "wi_up", "wo", "router"):
    gn = float(jnp.linalg.norm(g[name]))
    assert np.isfinite(gn) and gn > 0, (name, gn)
print("OK")
"""


def test_sharded_moe_paths_agree_and_train():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=590)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout
