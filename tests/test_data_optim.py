"""Data pipeline + optimizer + checkpoint behaviour tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.data.federated import FederatedCorpus, dirichlet_partition
from repro.data.synthetic import make_domains, sample_tokens
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         linear_schedule)


def test_domains_are_learnable_and_distinct():
    """A bigram model of domain A must beat chance on A and lose on B."""
    domains = make_domains(0, 2, vocab=64, branching=4)
    rng = np.random.default_rng(0)
    seq_a = sample_tokens(domains[0], rng, 64, 32)
    # empirical bigram counts from domain A
    counts = np.ones((64, 64))
    for row in seq_a:
        for a, b in zip(row[:-1], row[1:]):
            counts[a, b] += 1
    probs = counts / counts.sum(1, keepdims=True)

    def nll(seqs):
        tot, n = 0.0, 0
        for row in seqs:
            for a, b in zip(row[:-1], row[1:]):
                tot -= np.log(probs[a, b])
                n += 1
        return tot / n

    test_a = sample_tokens(domains[0], np.random.default_rng(1), 32, 32)
    test_b = sample_tokens(domains[1], np.random.default_rng(1), 32, 32)
    assert nll(test_a) < np.log(64) - 0.5     # far better than uniform
    assert nll(test_b) > nll(test_a) + 0.5    # domains distinct


def test_device_batches_deterministic():
    fc = FederatedCorpus.build(seed=0, n_devices=4, n_domains=2, vocab=128)
    b1 = fc.device_batch(1, 4, 16, step=3)
    b2 = fc.device_batch(1, 4, 16, step=3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = fc.device_batch(1, 4, 16, step=4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    fc = FederatedCorpus.build(seed=0, n_devices=2, n_domains=2, vocab=128)
    b = fc.device_batch(0, 2, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_dirichlet_partition_skew():
    rng = np.random.default_rng(0)
    labels = dirichlet_partition(rng, 64, 4, alpha=0.1)
    assert labels.shape == (64,)
    assert set(labels.tolist()) <= set(range(4))


def test_schedules():
    s = cosine_schedule(1.0, 100, warmup=10)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < float(s(50)) < float(s(10))
    lin = linear_schedule(1.0, 100, warmup=0)
    assert abs(float(lin(0)) - 1.0) < 1e-5
    assert float(lin(100)) == 0.0


def test_adamw_bias_correction_first_step():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 0.5)}
    new, opt, _ = adamw_update(g, opt, params, lr=0.1, clip_norm=0.0)
    # with bias correction the first step is ~ -lr * sign(g)
    np.testing.assert_allclose(np.asarray(new["w"]), -0.1, rtol=1e-3)


def test_grad_clip_caps_update_norm():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, stats = adamw_update(g, opt, params, lr=0.1, clip_norm=1.0)
    assert float(stats["grad_norm"]) > 1e5  # reported pre-clip


def test_bf16_state_dtype():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = adamw_init(params, state_dtype=jnp.bfloat16)
    assert opt["m"]["w"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip_with_bf16():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.arange(3, dtype=jnp.int32)}}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck.npz")
        save_pytree(tree, path)
        back = load_pytree(tree, path)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
