import os

# Tests run on the single host CPU device (the dry-run and ONLY the
# dry-run forces 512 placeholder devices, inside its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
