"""Paged-KV serving tests (ISSUE 4).

Covers:
  * per-family bit-identity: the block-paged engine emits token-for-token
    the same greedy output as the contiguous engine on one arch per
    decode-cache family (dense, moe, ssm, hybrid, vlm, encdec);
  * mixed-length Poisson-style traffic with prefix sharing: identical
    outputs, preamble blocks pooled once, admission bounded by the pool;
  * allocator properties: no double-free, refcounts hit zero iff no slot
    maps the block, diverged suffixes never alias shared prefixes;
  * the Pallas paged-attention kernel against the gather oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedAllocator, PagedServeEngine, ServeEngine, \
    Temperature
from repro.serve import paged as pg

# one arch per decode-cache family (dense + the five from test_serve_engine)
PAGED_FAMILY_ARCHS = [
    "tinyllama-1.1b",    # dense: stacked KV blocks
    "qwen2-moe-a2.7b",   # moe: stacked KV blocks + routed FFN
    "mamba2-1.3b",       # ssm: recurrent state only (no paged leaves)
    "zamba2-7b",         # hybrid: paged shared-attn KV + slot mamba state
    "paligemma-3b",      # vlm: patch-offset KV
    "whisper-small",     # encdec: paged self KV + slot cross/memory
]


def family_batch(cfg, P, seed=3):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, P), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    dt = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["patches"] = (jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (1, cfg.frontend_tokens, cfg.d_model)) * 0.05).astype(dt)
    if cfg.arch_type == "encdec":
        batch["frames"] = (jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (1, cfg.frontend_tokens, cfg.d_model)) * 0.05).astype(dt)
    return batch


def run_engine(cls, params, cfg, batches, lengths, max_len, **kw):
    eng = cls(params, cfg, max_len=max_len, **kw)
    for b, (_, g) in zip(batches, lengths):
        eng.submit(b, max_new=g)
    comps = eng.run()
    return {u: c.tokens.tolist() for u, c in comps.items()}, eng


@pytest.mark.parametrize("arch", PAGED_FAMILY_ARCHS)
def test_paged_engine_bit_identical_to_contiguous(arch):
    cfg = get_config(arch, variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(6, 4), (9, 6), (6, 5)]  # two distinct prompt shapes
    batches = [family_batch(cfg, p, seed=10 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    contig, _ = run_engine(ServeEngine, params, cfg, batches, lengths,
                           max_len, n_slots=2, seg_len=3, seed=0)
    paged, eng = run_engine(PagedServeEngine, params, cfg, batches, lengths,
                            max_len, n_slots=2, seg_len=3, seed=0,
                            block_len=4)
    assert paged == contig
    # every held block was released back to the pool
    assert eng.alloc.n_free == eng.alloc.n_blocks - 1
    assert not eng._slot_blocks


def test_paged_prefix_sharing_mixed_traffic():
    """Shared-preamble traffic through a pool too small for worst-case
    admission: outputs still match the contiguous engine, preamble
    blocks are pooled once, and concurrency is pool-bounded."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    pre = rng.integers(0, cfg.vocab_size, (1, 8))  # 2 full blocks @ bl=4
    gens = [5, 7, 4, 6, 5, 3]
    batches, lengths = [], []
    for i, g in enumerate(gens):
        sfx = rng.integers(0, cfg.vocab_size, (1, 4))
        batches.append({"tokens": jnp.asarray(
            np.concatenate([pre, sfx], 1), jnp.int32)})
        lengths.append((12, g))
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)

    contig, _ = run_engine(ServeEngine, params, cfg, batches, lengths,
                           max_len, n_slots=4, seg_len=3, seed=0)
    paged, eng = run_engine(PagedServeEngine, params, cfg, batches, lengths,
                            max_len, n_slots=4, seg_len=3, seed=0,
                            block_len=4, n_blocks=14)  # 13 allocatable
    assert paged == contig
    assert eng.stats["shared_blocks"] > 0          # preamble reused
    assert eng.stats["peak_live_blocks"] <= 13     # never over the pool
    assert eng.alloc.n_free == 13                  # fully drained
    # pooled keys drained with the refcounts
    assert not eng.alloc._bid_of and not eng.alloc._key_of


def test_paged_engine_rejects_request_larger_than_pool():
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = PagedServeEngine(params, cfg, n_slots=1, max_len=32, block_len=4,
                           n_blocks=4)  # 3 allocatable = 12 tokens
    with pytest.raises(ValueError, match="blocks"):
        eng.submit({"tokens": jnp.zeros((1, 10), jnp.int32)}, max_new=8)


def test_paged_sharing_never_aliases_diverged_suffixes():
    """Two identical prompts, stochastic sampling: prefix blocks are
    shared but each request's generated suffix lives in private blocks,
    so both still match their solo runs exactly."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0,
                                          cfg.vocab_size)}
    g, max_len = 6, M.decode_capacity(cfg, 8, 6)
    sampler = Temperature(0.8)
    outs = {}
    for cls, kw in [(ServeEngine, {}), (PagedServeEngine,
                                        {"block_len": 4})]:
        eng = cls(params, cfg, n_slots=2, max_len=max_len, seg_len=3,
                  seed=0, sampler=sampler, **kw)
        eng.submit(batch, max_new=g, uid=0)
        eng.submit(batch, max_new=g, uid=1)
        comps = eng.run()
        outs[cls.__name__] = {u: c.tokens.tolist() for u, c in comps.items()}
    paged = outs["PagedServeEngine"]
    # different per-uid keys -> the two suffixes diverge...
    assert paged[0] != paged[1]
    # ...and sharing the prompt blocks changed nothing vs contiguous
    assert paged == outs["ServeEngine"]


# ---------------------------------------------------------------------------
# allocator properties
# ---------------------------------------------------------------------------

def test_allocator_refcounts_and_double_free():
    al = PagedAllocator(6, 4)  # blocks 1..5
    assert al.n_free == 5 and pg.TRASH == 0
    a, fresh_a = al.acquire(("k", 1))
    b, fresh_b = al.acquire(("k", 1))
    assert a == b and fresh_a and not fresh_b and al.refcount[a] == 2
    c = al.alloc()
    assert c != a and al.refcount[c] == 1
    al.release(a)
    assert al.refcount[a] == 1 and al.lookup(("k", 1)) == a
    al.release(a)  # refcount 0 <=> no holder left: key evicted, block freed
    assert al.refcount[a] == 0 and al.lookup(("k", 1)) is None
    assert a in al.free_ids()
    with pytest.raises(ValueError, match="double free"):
        al.release(a)
    with pytest.raises(ValueError, match="trash"):
        al.release(pg.TRASH)
    al.release(c)
    assert al.n_free == 5 and al.n_live == 0


def test_allocator_exhaustion_and_key_reuse():
    al = PagedAllocator(3, 4)  # 2 allocatable
    x = al.alloc()
    y, _ = al.acquire(("p",))
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc()
    # a shared hit still works with an empty free list
    y2, fresh = al.acquire(("p",))
    assert y2 == y and not fresh
    al.release(y)
    al.release(y2)
    al.release(x)
    # freed ids recycle; the old key is gone
    z, fresh = al.acquire(("p",))
    assert fresh and al.n_free == 1 and z in (x, y)


def test_prefix_keys_depend_on_block_index_and_modality():
    bl = 4
    b1 = {"tokens": np.arange(8)[None]}
    b2 = {"tokens": np.arange(8)[None],
          "patches": np.ones((1, 2, 4), np.float32)}
    k1 = pg.prefix_keys(b1, 2, bl, 0)
    assert len(set(k1)) == 2                      # per-block keys differ
    assert pg.prefix_keys(b1, 2, bl, 0) == k1     # deterministic
    assert pg.prefix_keys(b2, 2, bl, 0) != k1     # modality in the key
    # frontend-only blocks (token prefix empty) still get distinct keys
    kf = pg.prefix_keys(b2, 2, bl, 8)
    assert len(set(kf)) == 2


# ---------------------------------------------------------------------------
# Pallas kernel vs gather oracle
# ---------------------------------------------------------------------------

def test_paged_attention_kernel_matches_ref():
    from repro.kernels.paged_attn.ops import paged_decode_attention
    from repro.kernels.paged_attn.ref import paged_attention_ref
    rng = np.random.default_rng(0)
    for (B, H, KH, D, nb, bl, nbt), window, softcap in [
            ((3, 8, 4, 32, 10, 4, 4), 0, 0.0),   # GQA
            ((2, 4, 4, 16, 8, 8, 3), 0, 30.0),   # MHA + softcap
            ((4, 8, 2, 32, 12, 4, 5), 6, 0.0)]:  # sliding window
        q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(nb, bl, KH, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(nb, bl, KH, D)), jnp.float32)
        bt = jnp.asarray(rng.integers(0, nb, size=(B, nbt)), jnp.int32)
        pos = jnp.asarray(rng.integers(0, nbt * bl, size=(B,)), jnp.int32)
        ref = paged_attention_ref(q, kp, vp, bt, pos, window=window,
                                  softcap=softcap)
        out = paged_decode_attention(q, kp, vp, bt, pos, window=window,
                                     softcap=softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_paged_decode_step_pallas_matches_gather():
    """cfg.use_pallas routes the paged read through the kernel; logits of
    the live slot must match the jnp gather path."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (1, 9), 0,
                                          cfg.vocab_size)}
    logits0, pc = M.prefill(params, cfg, batch)
    bl = 4
    cache = M.init_paged_cache(cfg, 2, 9, bl)
    sub = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, 1, 12), pc)
    cache = M.scatter_prefill_paged(cfg, cache, sub, 0,
                                    jnp.asarray([1, 2, 3]),
                                    jnp.asarray([True] * 3), block_len=bl)
    bt = jnp.asarray([[1, 2, 3, 4, 0], [0, 0, 0, 0, 0]], jnp.int32)
    tok = jnp.asarray([[int(jnp.argmax(logits0))], [0]], jnp.int32)
    pos = jnp.asarray([9, 0], jnp.int32)
    ref, _ = M.decode_step(params, cfg, cache, tok, pos, block_tables=bt)
    pal, _ = M.decode_step(params, cfg.replace(use_pallas=True), cache, tok,
                           pos, block_tables=bt)
    np.testing.assert_allclose(np.asarray(pal[0]), np.asarray(ref[0]),
                               atol=1e-4, rtol=1e-4)
