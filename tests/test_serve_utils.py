"""Unit tests for serving-launcher cache alignment (launch/serve.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import pad_cache_to


def test_pad_cache_same_shape_copies():
    dst = jnp.zeros((2, 8, 4))
    src = jnp.ones((2, 8, 4), jnp.float16)
    out = pad_cache_to({"k": dst}, {"k": src})
    assert out["k"].dtype == dst.dtype
    np.testing.assert_array_equal(np.asarray(out["k"]), 1.0)


def test_pad_cache_grows_single_seq_axis():
    dst = jnp.zeros((2, 8, 4))
    src = jnp.ones((2, 5, 4))
    out = pad_cache_to(dst, src)
    np.testing.assert_array_equal(np.asarray(out[:, :5]), 1.0)
    np.testing.assert_array_equal(np.asarray(out[:, 5:]), 0.0)


def test_pad_cache_rejects_multi_dim_mismatch():
    dst = jnp.zeros((2, 8, 4))
    with pytest.raises(ValueError, match="more than one dim"):
        pad_cache_to(dst, jnp.ones((3, 5, 4)))     # batch AND seq differ
    with pytest.raises(ValueError, match="more than one dim"):
        pad_cache_to(dst, jnp.ones((2, 5, 4, 1)))  # rank mismatch
