"""Unit tests for the model-layer serving cache contract.

``prefill_into_cache`` / ``graft_cache_entry`` replaced the two
divergent client-side helpers (launch/serve.py ``pad_cache_to`` raised
on multi-dim mismatch, examples ``graft`` silently fell through) — the
checked semantics live in ONE place now.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models.model import (decode_capacity, decode_pos0,
                                graft_cache_entry, prefill_into_cache)


def test_graft_same_shape_copies():
    dst = jnp.zeros((2, 8, 4))
    src = jnp.ones((2, 8, 4), jnp.float16)
    out = graft_cache_entry(dst, src)
    assert out.dtype == dst.dtype
    np.testing.assert_array_equal(np.asarray(out), 1.0)


def test_graft_grows_single_seq_axis():
    dst = jnp.zeros((2, 8, 4))
    src = jnp.ones((2, 5, 4))
    out = graft_cache_entry(dst, src)
    np.testing.assert_array_equal(np.asarray(out[:, :5]), 1.0)
    np.testing.assert_array_equal(np.asarray(out[:, 5:]), 0.0)


def test_graft_rejects_multi_dim_mismatch():
    dst = jnp.zeros((2, 8, 4))
    with pytest.raises(ValueError, match="more than one dim"):
        graft_cache_entry(dst, jnp.ones((3, 5, 4)))     # batch AND seq differ
    with pytest.raises(ValueError, match="more than one dim"):
        graft_cache_entry(dst, jnp.ones((2, 5, 4, 1)))  # rank mismatch


def test_graft_rejects_prefill_longer_than_capacity():
    dst = jnp.zeros((2, 8, 4))
    with pytest.raises(ValueError, match="exceeds"):
        graft_cache_entry(dst, jnp.ones((2, 9, 4)))


def test_capacity_is_exact_no_off_by_one():
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    assert decode_capacity(cfg, 32, 16) == 48      # P + G, not P + G + 1
    assert decode_pos0(cfg, 32) == 32
    vlm = get_config("paligemma-3b", variant="reduced")
    off = vlm.frontend_tokens
    assert decode_capacity(vlm, 32, 16) == off + 48
    assert decode_pos0(vlm, 32) == off + 32


def test_prefill_into_cache_rejects_foreign_tree():
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((2, 8), jnp.int32)
    _, pc = M.prefill(params, cfg, {"tokens": toks})
    # a decode cache built for a different batch size must not graft
    bad = M.init_decode_cache(cfg, 3, 16)
    with pytest.raises(ValueError, match="more than one dim"):
        prefill_into_cache(cfg, bad, pc)


def test_hybrid_tail_prefill_into_cache_matches_forward():
    """zamba2 with a tail stack (n_layers % period != 0): the separately
    stored ``tail_attn`` prefill entry must land in the LAST row of the
    stacked decode attn cache."""
    cfg = get_config("zamba2-7b", variant="reduced").replace(n_layers=5)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    h, _, _, _ = M.backbone(params, cfg, {"tokens": toks})
    ref_logits = M._head(params, cfg, h[:, -1:])[:, 0]

    _, pc = M.prefill(params, cfg, {"tokens": toks[:, :S - 1]})
    assert "tail" in pc and pc["tail"] is not None
    cache = prefill_into_cache(cfg, M.init_decode_cache(cfg, B, S), pc)
    pos = jnp.full((B,), S - 1, jnp.int32)
    logits, _ = M.decode_step(params, cfg, cache, toks[:, S - 1:S], pos)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)


def test_encdec_prefill_into_cache_matches_forward():
    """whisper: prefill self/cross/memory graft + one decode step equals
    the full decoder forward (the path the old launcher SystemExit'd)."""
    cfg = get_config("whisper-small", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    frames = (jax.random.normal(jax.random.PRNGKey(4),
                                (B, cfg.frontend_tokens, cfg.d_model))
              * 0.05).astype(jnp.dtype(cfg.dtype))
    h, _, _, _ = M.backbone(params, cfg, {"tokens": toks, "frames": frames})
    ref_logits = M._head(params, cfg, h[:, -1:])[:, 0]

    _, pc = M.prefill(params, cfg,
                      {"tokens": toks[:, :S - 1], "frames": frames})
    cache = prefill_into_cache(cfg, M.init_decode_cache(cfg, B, S), pc)
    pos = jnp.full((B,), S - 1, jnp.int32)
    logits, _ = M.decode_step(params, cfg, cache, toks[:, S - 1:S], pos)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)
