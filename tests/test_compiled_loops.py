"""Equivalence + accounting tests for the compiled federated hot loops.

The scan-compiled epoch drivers (device local training, Phase II
distillation, Phase III tuning) and the vmapped fleet driver must
reproduce the historical per-step Python loops at fixed seeds — same
batches, same lr schedule, same updates.  Also pins the comm-cost
accounting fix: uploads are billed from the *configured* device model's
parameter count (Eq. 5 / Fig. 8), not the in-memory reduced tree.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distill, tuning
from repro.core import vaa as vaa_mod
from repro.data.federated import FederatedCorpus
from repro.federated.device import (DeviceSpec, device_upload_bytes,
                                    train_device, train_fleet)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.utils.pytree import tree_bytes

V = 64
SMALL = dict(vocab_size=V, dtype="float32", remat=False,
             attn_chunk_q=16, attn_chunk_k=16, loss_chunk=16)

CFG_A = ModelConfig(name="scan-a-tiny", n_layers=1, d_model=32, n_heads=2,
                    n_kv_heads=2, head_dim=16, d_ff=64,
                    norm_type="layernorm", act="gelu", mlp_gated=False,
                    pos_embedding="sinusoidal", **SMALL).validate()
CFG_B = ModelConfig(name="scan-b-tiny", n_layers=2, d_model=48, n_heads=2,
                    n_kv_heads=2, head_dim=24, d_ff=96, **SMALL).validate()
MOE_CFG = ModelConfig(name="scan-moe-tiny", arch_type="moe", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                      d_ff=64, n_experts=4, top_k=2, moe_d_ff=64,
                      n_shared_experts=1, **SMALL).validate()

STEPS, BATCH, SEQ = 5, 4, 16


@pytest.fixture(scope="module")
def corpus():
    return FederatedCorpus.build(seed=0, n_devices=5, n_domains=2, vocab=V)


@pytest.fixture(scope="module")
def fleet():
    return [DeviceSpec(0, CFG_A, 0, 0), DeviceSpec(1, CFG_B, 1, 0),
            DeviceSpec(2, CFG_A, 0, 1), DeviceSpec(3, CFG_A, 0, 1),
            DeviceSpec(4, CFG_B, 1, 1)]


def _tree_max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32) -
                                     y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# device local training
# ---------------------------------------------------------------------------

def test_device_scan_matches_per_step(corpus):
    kw = dict(steps=STEPS, batch=BATCH, seq_len=SEQ, seed=0)
    spec = DeviceSpec(0, CFG_A, 0, 0)
    ref = train_device(spec, corpus, compiled=False, **kw)
    got = train_device(spec, corpus, compiled=True, **kw)
    # one compiled scan over pre-generated batches == the per-step loop,
    # bit for bit
    np.testing.assert_array_equal(np.array(got["losses"]),
                                  np.array(ref["losses"]))
    assert _tree_max_diff(got["params"], ref["params"]) == 0.0


def test_fleet_vmap_matches_per_device(corpus, fleet):
    kw = dict(steps=STEPS, batch=BATCH, seq_len=SEQ, seed=0)
    refs = [train_device(s, corpus, compiled=False, **kw) for s in fleet]
    got = train_fleet(fleet, corpus, **kw)
    assert [u["device_id"] for u in got] == [s.device_id for s in fleet]
    for r, g, spec in zip(refs, got, fleet):
        # vmap batches the per-device programs; XLA may re-associate the
        # loss reductions, so allow float32 round-off on the recorded
        # losses (parameters come out bit-identical in practice)
        np.testing.assert_allclose(np.array(g["losses"]),
                                   np.array(r["losses"]),
                                   rtol=0, atol=5e-6)
        assert _tree_max_diff(g["params"], r["params"]) < 1e-6
        assert g["arch_id"] == r["arch_id"] == spec.arch_id
        assert g["upload_bytes"] == r["upload_bytes"]
        np.testing.assert_array_equal(g["embedding"], r["embedding"])


# ---------------------------------------------------------------------------
# Phase II distillation
# ---------------------------------------------------------------------------

def test_distill_epoch_matches_per_step(corpus):
    hp = dict(alpha=1.0, beta=1.0, temperature=2.0, n_stages=2,
              vaa_heads=2, p_q=8)
    lr, warmup = 1e-3, 1
    t_params = M.init_params(jax.random.PRNGKey(7), CFG_B)
    s_params = M.init_params(jax.random.PRNGKey(8), CFG_A)
    vaa_params = vaa_mod.init_vaa(jax.random.PRNGKey(9), n_stages=2,
                                  d_student=CFG_A.d_model,
                                  d_teacher=CFG_B.d_model, d=16, n_heads=2,
                                  p_q=8)
    trainable = {"student": s_params, "vaa": vaa_params}

    step = jax.jit(distill.make_distill_step(
        CFG_A, CFG_B, optimizer_update=adamw_update, **hp))
    sched = cosine_schedule(lr, STEPS, warmup=warmup)
    ref_t, ref_o = trainable, adamw_init(trainable)
    ref_losses = []
    for s in range(STEPS):
        b = corpus.mixed_eval_batch(BATCH, SEQ, seed_salt=s)
        ref_t, ref_o, loss, _ = step(ref_t, ref_o, t_params, b, sched(s))
        ref_losses.append(float(loss))

    epoch = jax.jit(distill.make_distill_epoch(
        CFG_A, CFG_B, steps=STEPS, schedule=sched,
        optimizer_update=adamw_update, **hp))
    batches = corpus.mixed_eval_batches(STEPS, BATCH, SEQ)
    got_t, _, losses = epoch(trainable, adamw_init(trainable), t_params,
                             batches)
    # compiling the whole epoch as one program lets XLA re-associate the
    # chunked CE/KL reductions — allow float32 ulp-level round-off
    np.testing.assert_allclose(np.asarray(losses), np.array(ref_losses),
                               rtol=0, atol=5e-6)
    assert _tree_max_diff(got_t, ref_t) < 1e-5


# ---------------------------------------------------------------------------
# Phase III tuning
# ---------------------------------------------------------------------------

def test_tune_epoch_matches_per_step(corpus):
    lr, warmup = 5e-4, 1
    params = M.init_params(jax.random.PRNGKey(11), MOE_CFG)
    mask, opt0 = tuning.init_tuning(params)
    sched = cosine_schedule(lr, STEPS, warmup=warmup)

    step = jax.jit(tuning.make_tune_step(MOE_CFG, mask))
    ref_p, ref_o = params, opt0
    ref_losses = []
    for s in range(STEPS):
        b = corpus.mixed_eval_batch(BATCH, SEQ, seed_salt=10_000 + s)
        ref_p, ref_o, loss, _ = step(ref_p, ref_o, b, sched(s))
        ref_losses.append(float(loss))

    epoch = jax.jit(tuning.make_tune_epoch(MOE_CFG, mask, steps=STEPS,
                                           schedule=sched))
    batches = corpus.mixed_eval_batches(STEPS, BATCH, SEQ, seed_salt0=10_000)
    _, opt0b = tuning.init_tuning(params)
    got_p, _, losses = epoch(params, opt0b, batches)
    np.testing.assert_allclose(np.asarray(losses), np.array(ref_losses),
                               rtol=0, atol=5e-6)
    assert _tree_max_diff(got_p, ref_p) < 1e-5


# ---------------------------------------------------------------------------
# stacked batch generation contract
# ---------------------------------------------------------------------------

def test_stacked_batches_match_per_step_batches(corpus):
    stacked = corpus.device_batches(1, STEPS, BATCH, SEQ)
    assert stacked["tokens"].shape == (STEPS, BATCH, SEQ)
    for s in range(STEPS):
        b = corpus.device_batch(1, BATCH, SEQ, step=s)
        np.testing.assert_array_equal(np.asarray(stacked["tokens"][s]),
                                      np.asarray(b["tokens"]))
        np.testing.assert_array_equal(np.asarray(stacked["labels"][s]),
                                      np.asarray(b["labels"]))
    stacked = corpus.mixed_eval_batches(STEPS, BATCH, SEQ, seed_salt0=3)
    for s in range(STEPS):
        b = corpus.mixed_eval_batch(BATCH, SEQ, seed_salt=3 + s)
        np.testing.assert_array_equal(np.asarray(stacked["tokens"][s]),
                                      np.asarray(b["tokens"]))


# ---------------------------------------------------------------------------
# comm-cost accounting (Eq. 5 / Fig. 8)
# ---------------------------------------------------------------------------

def test_upload_bytes_from_configured_model():
    # billed from the config's param count at its configured dtype —
    # identical to the materialised tree for a directly-trained config
    p = M.init_params(jax.random.PRNGKey(0), CFG_A)
    assert device_upload_bytes(CFG_A) == tree_bytes(p) + 32 * 4


def test_upload_bytes_pins_gpt2():
    # GPT-2 (paper device model): 123,570,432 params @ bf16 + 32-float
    # embedding = 247,140,992 bytes one-shot upload
    from repro.configs.device_models import GPT2
    assert device_upload_bytes(GPT2) == 247_140_992


def test_build_fleet_plumbs_full_cfgs(corpus):
    # the simulation API can bill full-size models while training the
    # reduced stand-ins: full_cfgs maps each family to its paper model
    from repro.configs.device_models import GPT2, GPT2_MEDIUM
    from repro.federated.simulation import SimulationConfig, build_fleet
    sim = SimulationConfig(n_devices=5, n_domains=2, vocab=V, seq_len=SEQ)
    fleet = build_fleet(sim, corpus, [CFG_A, CFG_B],
                        full_cfgs=[GPT2, GPT2_MEDIUM])
    assert {s.arch_id for s in fleet} == {0, 1}
    for spec in fleet:
        assert spec.comm_cfg is (GPT2 if spec.arch_id == 0 else GPT2_MEDIUM)


def test_fleet_bills_full_variant_not_trained_reduction(corpus):
    # a device that trains a reduced CPU stand-in still bills the
    # configured full-size model's upload (module docstring contract)
    from repro.configs.device_models import GPT2
    spec = DeviceSpec(0, CFG_A, 0, 0, full_cfg=GPT2)
    up = train_device(spec, corpus, steps=2, batch=2, seq_len=8, seed=0)
    assert up["upload_bytes"] == device_upload_bytes(GPT2)
    assert up["upload_bytes"] > tree_bytes(up["params"])
