"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate the REDUCED variant of the
same family (2 layers, d_model <= 512, <= 4 experts), run one forward /
train step on CPU, assert output shapes + finiteness; run one decode
step against a cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import model as M
from repro.optim import adamw_init, adamw_update

ARCHS = sorted(ASSIGNED)


def tiny_batch(cfg, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    if cfg.arch_type == "vlm":
        batch["patches"] = jnp.ones((B, cfg.frontend_tokens, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    if cfg.arch_type == "encdec":
        batch["frames"] = jnp.ones((B, cfg.frontend_tokens, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = tiny_batch(cfg)
    loss, metrics = M.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert float(metrics["tokens"]) > 0

    # one full train step (grads + AdamW)
    opt = adamw_init(params)
    (l2, _), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    new_params, opt, stats = adamw_update(grads, opt, params, lr=1e-3)
    assert bool(jnp.isfinite(stats["grad_norm"]))
    # params changed
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch, variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 32
    cache = M.init_decode_cache(cfg, B, S)
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), 3, jnp.int32)
    logits, new_cache = M.decode_step(params, cfg, cache, tok, pos)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b",
                                  "deepseek-v3-671b", "mamba2-1.3b",
                                  "gemma2-9b", "zamba2-7b"])
def test_decode_matches_forward(arch):
    """prefill(x[:t]) + decode(x[t]) logits == forward(x[:t+1]) last logits."""
    cfg = get_config(arch, variant="reduced")
    if cfg.is_moe:
        # top-k routing can differ microscopically between paths; use top-1
        cfg = cfg.replace(top_k=1)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    B, S = 2, 16
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    # reference: full forward on S tokens
    h, _, _, _ = M.backbone(params, cfg, {"tokens": toks})
    ref_logits = M._head(params, cfg, h[:, -1:])[:, 0]

    # prefill on S-1 tokens, then one decode step for token S-1
    logits_p, pc = M.prefill(params, cfg, {"tokens": toks[:, :S - 1]})
    cache = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, B, S), pc)

    pos = jnp.full((B,), S - 1, jnp.int32)
    logits_d, _ = M.decode_step(params, cfg, cache, toks[:, S - 1:S], pos)

    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)


def test_vlm_decode_matches_forward():
    cfg = get_config("paligemma-3b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    B, S = 2, 12
    P = cfg.frontend_tokens
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    patches = jax.random.normal(jax.random.PRNGKey(4),
                                (B, P, cfg.d_model)) * 0.05
    batch = {"tokens": toks, "patches": patches}
    h, _, _, _ = M.backbone(params, cfg, batch)
    ref_logits = M._head(params, cfg, h[:, -1:])[:, 0]

    logits_p, pc = M.prefill(params, cfg,
                             {"tokens": toks[:, :S - 1], "patches": patches})
    cap = M.decode_capacity(cfg, S - 1, 1)  # == P + S, patch offset included
    cache = M.prefill_into_cache(cfg, M.init_decode_cache(cfg, B, cap), pc)
    pos = jnp.full((B,), M.decode_pos0(cfg, S - 1), jnp.int32)
    logits_d, _ = M.decode_step(params, cfg, cache, toks[:, S - 1:S], pos)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_history():
    """A gemma-style local layer must ignore tokens beyond the window."""
    cfg = get_config("gemma2-9b", variant="reduced").replace(
        n_layers=2, attn_pattern=("local", "full"), sliding_window=4)
    params = M.init_params(jax.random.PRNGKey(5), cfg)
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0,
                              cfg.vocab_size)
    h1, _, _, _ = M.backbone(params, cfg, {"tokens": toks})
    # perturb a token far outside every window of the final position
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    h2, _, _, _ = M.backbone(params, cfg, {"tokens": toks2})
    # the FULL layer still sees token 0, so hidden states differ...
    assert float(jnp.max(jnp.abs(h1 - h2))) > 0
    # ...but a pure-local config must not propagate it to the last position
    cfg_local = cfg.replace(attn_pattern=("local", "local"))
    params_l = M.init_params(jax.random.PRNGKey(5), cfg_local)
    a, _, _, _ = M.backbone(params_l, cfg_local, {"tokens": toks})
    b, _, _, _ = M.backbone(params_l, cfg_local, {"tokens": toks2})
    # positions >= 2*window away from token 0 (two local layers) unchanged
    np.testing.assert_allclose(np.asarray(a[:, 12:]), np.asarray(b[:, 12:]),
                               rtol=1e-5, atol=1e-5)
