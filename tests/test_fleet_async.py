"""Async / hierarchical fleet rounds: equivalence, robustness, scale-out.

The async driver's design invariants, each pinned here:
 * an ideal fleet (everyone online + on time, full participation) run in
   async rounds is BIT-FOR-BIT the synchronous one-shot ``train_fleet``
 * staleness-weighted merging with all-fresh reports IS the plain
   FedAvg ``tree_average`` (exact), and mixed-staleness weights match
   the closed-form FedAsync formula
 * traffic draws are pure functions of (seed, device, round): replays
   are bit-identical, and a dropped device rejoins exactly where its
   batch stream paused
 * deadline policies (drop / stale / standby) route late reports as
   documented; hierarchical mode merges identically to flat mode while
   billing the global link only per-bucket
 * multi-host sharding over a ("hosts",) mesh keeps lanes independent
   (async == sync still bitwise at equal host count; 1-host vs 4-host
   only differs by shape-dependent XLA fusion, <= 1 ulp)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.federated import FederatedCorpus
from repro.federated.async_fleet import train_fleet_async
from repro.federated.device import (DeviceSpec, TrafficModel, _device_step_fn,
                                    sample_traffic, train_fleet)
from repro.federated.server import (AsyncFleetConfig, FleetAggregator,
                                    staleness_weight)
from repro.federated.simulation import SimulationConfig, build_fleet
from repro.models.config import ModelConfig
from repro.optim import adamw_init, cosine_schedule
from repro.utils.pytree import tree_average

V = 64
SMALL = dict(vocab_size=V, dtype="float32", remat=False,
             attn_chunk_q=16, attn_chunk_k=16, loss_chunk=16)
CFG_A = ModelConfig(name="async-a-tiny", n_layers=1, d_model=32, n_heads=2,
                    n_kv_heads=2, head_dim=16, d_ff=64,
                    norm_type="layernorm", act="gelu", mlp_gated=False,
                    pos_embedding="sinusoidal", **SMALL).validate()
CFG_B = ModelConfig(name="async-b-tiny", n_layers=2, d_model=48, n_heads=2,
                    n_kv_heads=2, head_dim=24, d_ff=96, **SMALL).validate()

BATCH, SEQ = 4, 16
KW = dict(batch=BATCH, seq_len=SEQ)

MULTI = len(jax.devices()) >= 4
needs_multi = pytest.mark.skipif(
    not MULTI, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture(scope="module")
def corpus():
    return FederatedCorpus.build(seed=0, n_devices=8, n_domains=2, vocab=V)


def fleet_of(n, traffic=None):
    return [DeviceSpec(i, CFG_A if i % 2 else CFG_B, i % 2, i % 2,
                       traffic=traffic) for i in range(n)]


def _tree_eq(a, b):
    return all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _uploads_bitwise(ua, ub):
    return all(a["losses"] == b["losses"] and
               _tree_eq(a["params"], b["params"])
               for a, b in zip(ua, ub))


def _tree_max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# ideal async rounds == synchronous one-shot
# ---------------------------------------------------------------------------

def test_ideal_async_equals_sync_bitwise(corpus):
    fleet = fleet_of(5)
    acfg = AsyncFleetConfig(rounds=3, steps_per_round=2)
    asy, rep = train_fleet_async(fleet, corpus, acfg, **KW)
    sync = train_fleet(fleet, corpus, steps=6, **KW)
    assert _uploads_bitwise(asy, sync)
    assert rep["participation_rate"] == 1.0
    assert rep["staleness_hist"] == {0: 15}    # 5 devices x 3 rounds
    assert rep["lost_reports"] == 0


def test_round_slicing_matches_batch_stream(corpus):
    # the rejoin guarantee reduces to this: the round-sliced stream is a
    # slice of the full stream, per (device, step), independent of when
    # the slices are generated
    full = corpus.device_batches(1, 6, BATCH, SEQ)
    tail = corpus.device_batches(1, 3, BATCH, SEQ, start=3)
    sliced = jax.tree.map(lambda x: x[3:], full)
    assert _tree_eq(sliced, tail)


def test_dropped_device_rejoins_where_it_paused(corpus):
    # one device, online only on even rounds (availability window):
    # after 4 rounds of 2 steps it has trained local steps 0..3 of an
    # 8-step schedule horizon.  The per-step reference loop over the
    # SAME stream must match bit-for-bit — i.e. the schedule and batch
    # stream advance with the device's local step, not the round index.
    tm = TrafficModel(avail_period=2, avail_duty=1)
    spec = DeviceSpec(0, CFG_A, 0, 0, traffic=tm)
    acfg = AsyncFleetConfig(rounds=4, steps_per_round=2)
    ups, rep = train_fleet_async([spec], corpus, acfg, **KW)
    assert len(ups[0]["losses"]) == 4          # trained rounds 0 and 2

    from repro.federated.device import _device_init
    params, opt = _device_init(spec, 0, "")
    sched = cosine_schedule(3e-3, 8, warmup=max(8 // 20, 1))
    step_fn = _device_step_fn(CFG_A)
    batches = corpus.device_batches(0, 4, BATCH, SEQ)
    for s in range(4):
        b = jax.tree.map(lambda x: x[s], batches)
        params, opt, _ = step_fn(params, opt, b, sched(s))
    # vmapped-scan vs per-step jit compile differently, so ulp tolerance
    assert _tree_max_diff(ups[0]["params"], params) < 1e-6


def test_traffic_replay_deterministic(corpus):
    tm = TrafficModel(dropout_p=0.4, median_latency_s=2.0, latency_sigma=1.0)
    fleet = fleet_of(6, traffic=tm)
    acfg = AsyncFleetConfig(rounds=3, steps_per_round=2, participation=0.7,
                            deadline_s=1.5, seed=3)
    u1, r1 = train_fleet_async(fleet, corpus, acfg, **KW)
    u2, r2 = train_fleet_async(fleet, corpus, acfg, **KW)
    assert _uploads_bitwise(u1, u2)
    assert r1["rounds"] == r2["rounds"]
    # and the draws really are per-(seed, device, round)
    for r in range(3):
        for s in fleet:
            assert sample_traffic(s, r, 3) == sample_traffic(s, r, 3)
    assert any(sample_traffic(fleet[0], r, 3) !=
               sample_traffic(fleet[0], r, 4) for r in range(8))


# ---------------------------------------------------------------------------
# staleness-weighted merging
# ---------------------------------------------------------------------------

def _report(i, key, staleness):
    return {"device_id": i, "staleness": staleness,
            "params": {"w": jax.random.normal(jax.random.PRNGKey(key),
                                              (4, 3))}}


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_all_fresh_merge_is_exact_fedavg(seed):
    reports = [_report(i, seed * 10 + i, 0) for i in range(4)]
    agg = FleetAggregator(AsyncFleetConfig())
    merged = agg.merge_round("b", reports)
    avg = tree_average([r["params"] for r in reports])
    assert _tree_eq(merged, avg)


@pytest.mark.parametrize("seed", [0, 1])
def test_mixed_staleness_matches_closed_form(seed):
    acfg = AsyncFleetConfig(alpha=0.6, staleness_power=0.5)
    staleness = [0, 2, 1]
    reports = [_report(i, seed * 10 + i, t)
               for i, t in enumerate(staleness)]
    agg = FleetAggregator(acfg)
    merged = agg.merge_round("b", reports)
    ws = np.array([staleness_weight(0.6, t, 0.5) for t in staleness])
    ws = ws / ws.sum()
    ref = sum(w * np.asarray(r["params"]["w"], np.float32)
              for w, r in zip(ws, reports))
    np.testing.assert_allclose(np.asarray(merged["w"]), ref, rtol=1e-5,
                               atol=1e-7)
    # fresher reports weigh more
    assert staleness_weight(0.6, 0, 0.5) > staleness_weight(0.6, 1, 0.5) \
        > staleness_weight(0.6, 2, 0.5)


def test_server_momentum_mixes_previous_aggregate():
    acfg = AsyncFleetConfig(server_momentum=0.5)
    agg = FleetAggregator(acfg)
    a = agg.merge_round("b", [_report(0, 0, 0)])
    b_new = _report(1, 1, 0)
    mixed = agg.merge_round("b", [b_new])
    ref = 0.5 * np.asarray(a["w"]) + 0.5 * np.asarray(b_new["params"]["w"])
    np.testing.assert_allclose(np.asarray(mixed["w"]), ref, rtol=1e-6)


# ---------------------------------------------------------------------------
# deadline policies
# ---------------------------------------------------------------------------

def _slow_fleet(n):
    # deterministic latency (sigma=0): always 3s against a 2s deadline,
    # i.e. every report is exactly one round late
    return fleet_of(n, traffic=TrafficModel(median_latency_s=3.0,
                                            latency_sigma=0.0))


def test_deadline_stale_carries_reports_one_round(corpus):
    acfg = AsyncFleetConfig(rounds=3, steps_per_round=2, deadline_s=2.0,
                            deadline_policy="stale")
    _, rep = train_fleet_async(_slow_fleet(4), corpus, acfg, **KW)
    rounds = rep["rounds"]
    assert rounds[0]["reported"] == 0
    assert rounds[1]["stale_merged"] == 4 and rounds[2]["stale_merged"] == 4
    assert rep["staleness_hist"] == {1: 8}
    assert rep["staleness_p95"] == 1.0
    # the final round's reports never matured inside the horizon
    assert rep["lost_reports"] == 4


def test_deadline_drop_discards_late_reports(corpus):
    acfg = AsyncFleetConfig(rounds=3, steps_per_round=2, deadline_s=2.0,
                            deadline_policy="drop")
    _, rep = train_fleet_async(_slow_fleet(4), corpus, acfg, **KW)
    assert rep["merged_reports"] == 0
    assert rep["lost_reports"] == 12
    assert all(r["late_dropped"] == 4 for r in rep["rounds"])
    assert rep["comm_bytes_global"] == 0


def test_deadline_standby_over_selects(corpus):
    acfg = AsyncFleetConfig(rounds=2, steps_per_round=2, participation=0.5,
                            deadline_policy="standby", over_select=0.25)
    _, rep = train_fleet_async(fleet_of(8), corpus, acfg, **KW)
    # target ceil(0.5 * 8) = 4, over-selected to ceil(4 * 1.25) = 5
    assert all(r["selected"] == 5 for r in rep["rounds"])
    ref = AsyncFleetConfig(rounds=2, steps_per_round=2, participation=0.5)
    _, rep2 = train_fleet_async(fleet_of(8), corpus, ref, **KW)
    assert all(r["selected"] == 4 for r in rep2["rounds"])


def test_bad_policy_rejected():
    with pytest.raises(ValueError, match="deadline_policy"):
        AsyncFleetConfig(deadline_policy="wait-forever").validate()
    with pytest.raises(ValueError, match="participation"):
        AsyncFleetConfig(participation=0.0).validate()


# ---------------------------------------------------------------------------
# hierarchical aggregation + comm accounting
# ---------------------------------------------------------------------------

def test_hierarchical_merges_like_flat_and_bills_less(corpus):
    fleet = fleet_of(6)
    flat_cfg = AsyncFleetConfig(rounds=2, steps_per_round=2)
    hier_cfg = dataclasses.replace(flat_cfg, hierarchical=True)
    _, flat = train_fleet_async(fleet, corpus, flat_cfg, **KW)
    _, hier = train_fleet_async(fleet, corpus, hier_cfg, **KW)
    # sub-servers compute the same per-bucket merge, only routing differs
    assert set(flat["aggregates"]) == set(hier["aggregates"])
    for k in flat["aggregates"]:
        assert _tree_eq(flat["aggregates"][k], hier["aggregates"][k])
    # flat: every device report crosses the global link; hierarchical:
    # one bucket aggregate per (bucket, round) does
    assert flat["comm_bytes_edge"] == 0
    assert hier["comm_bytes_edge"] == flat["comm_bytes_global"]
    assert 0 < hier["comm_bytes_global"] < flat["comm_bytes_global"]


def test_report_carries_participation_columns(corpus):
    acfg = AsyncFleetConfig(rounds=2, steps_per_round=2, participation=0.6)
    _, rep = train_fleet_async(fleet_of(5), corpus, acfg, **KW)
    for key in ("mode", "rounds", "participation_rate", "staleness_hist",
                "staleness_p95", "comm_bytes_global", "comm_bytes_edge",
                "lost_reports", "n_hosts"):
        assert key in rep
    for row in rep["rounds"]:
        for key in ("round", "online", "selected", "reported",
                    "stale_merged", "late_dropped", "participation_rate",
                    "comm_bytes"):
            assert key in row
    # partial participation really holds reports back
    assert all(r["selected"] == 3 for r in rep["rounds"])
    assert rep["participation_rate"] <= 0.6


# ---------------------------------------------------------------------------
# build_fleet plumbing
# ---------------------------------------------------------------------------

def test_build_fleet_validates_full_cfgs(corpus):
    sim = SimulationConfig(n_devices=4, vocab=V, seq_len=SEQ)
    with pytest.raises(ValueError, match="async-b-tiny"):
        build_fleet(sim, corpus, [CFG_A, CFG_B], full_cfgs=[CFG_A])
    with pytest.raises(ValueError, match="parallel"):
        build_fleet(sim, corpus, [CFG_A], full_cfgs=[CFG_A, CFG_B])
    with pytest.raises(ValueError, match="straggler profile"):
        build_fleet(sim, corpus, [CFG_A], traffic="bogus")


def test_build_fleet_applies_traffic_profile(corpus):
    sim = SimulationConfig(n_devices=4, vocab=V, seq_len=SEQ)
    fleet = build_fleet(sim, corpus, [CFG_A, CFG_B], traffic="harsh")
    assert all(s.traffic is not None and s.traffic.dropout_p == 0.3
               for s in fleet)


# ---------------------------------------------------------------------------
# multi-host bucketed training
# ---------------------------------------------------------------------------

@needs_multi
def test_multihost_async_equals_sync_bitwise(corpus):
    fleet = fleet_of(6)
    acfg = AsyncFleetConfig(rounds=2, steps_per_round=2)
    asy, rep = train_fleet_async(fleet, corpus, acfg, n_hosts=4, **KW)
    sync = train_fleet(fleet, corpus, steps=4, n_hosts=4, **KW)
    assert _uploads_bitwise(asy, sync)
    assert rep["n_hosts"] == 4


@needs_multi
def test_multihost_matches_single_host_to_ulp(corpus):
    # lanes are independent, but padding the stacked device axis changes
    # array shapes and with them XLA fusion choices — so cross-host-count
    # equality is to float32-ulp tolerance, not bitwise
    fleet = fleet_of(6)
    u1 = train_fleet(fleet, corpus, steps=4, **KW)
    u4 = train_fleet(fleet, corpus, steps=4, n_hosts=4, **KW)
    for a, b in zip(u1, u4):
        assert _tree_max_diff(a["params"], b["params"]) < 1e-6


@needs_multi
def test_fleet_state_shards_over_hosts(corpus):
    from repro.federated.device import (_device_init, _pad_lanes,
                                        _shard_bucket, _stack_trees)
    from repro.launch.mesh import make_fleet_mesh
    from repro.sharding import host_resident_bytes

    inits = [_device_init(s, 0, "") for s in fleet_of(6) if s.cfg == CFG_A]
    params = _stack_trees([p for p, _ in inits])
    b1 = host_resident_bytes(params)
    mesh = make_fleet_mesh(4)
    n_pad = (-3) % 4
    (sharded,) = _shard_bucket(mesh, _pad_lanes(params, n_pad))
    b4 = host_resident_bytes(sharded)
    # 3 lanes pad to 4, shard 1 per host: 1/3 of the unsharded bytes
    assert b1 / b4 >= 1.8


def test_make_fleet_mesh_rejects_oversubscription():
    from repro.launch.mesh import make_fleet_mesh
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_fleet_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# end-to-end through the simulation driver
# ---------------------------------------------------------------------------

def test_run_deepfusion_async_smoke(corpus):
    from repro.federated.server import ServerConfig
    from repro.federated.simulation import run_deepfusion

    moe_cfg = ModelConfig(name="async-moe-tiny", arch_type="moe", n_layers=1,
                          d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                          d_ff=64, n_experts=2, top_k=1, moe_d_ff=64,
                          **SMALL).validate()
    sim = SimulationConfig(n_devices=4, n_domains=2, vocab=V, seq_len=SEQ,
                           device_steps=4, device_batch=BATCH, seed=0)
    scfg = ServerConfig(moe_cfg=moe_cfg, distill_steps=4, distill_batch=4,
                        tune_steps=4, tune_batch=4, seq_len=SEQ, n_stages=1,
                        p_q=16, vaa_dim=32,
                        schedule=AsyncFleetConfig(rounds=2,
                                                  steps_per_round=0))
    _, report = run_deepfusion(sim, scfg, [CFG_A, CFG_B],
                               log=lambda s: None, traffic="mild")
    fr = report["fleet"]
    assert fr["participation_rate"] > 0
    assert len(fr["rounds"]) == 2
    # steps_per_round=0 derives from the sim: 4 steps over 2 rounds
    assert sum(len(u["losses"]) for u in report["uploads"]) <= 4 * 2 * 2
    assert np.isfinite(report["metrics"]["log_ppl"])
