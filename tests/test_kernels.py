"""Per-kernel correctness sweeps: Pallas (interpret=True) vs ref.py oracles.

Shapes and dtypes are swept; every kernel must match its pure-jnp oracle
to tight tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_dispatch.kernel import gather_scatter_add_rows
from repro.kernels.moe_dispatch.ops import (capacity_positions, token_combine,
                                            token_dispatch)
from repro.kernels.moe_dispatch.ref import gather_scatter_add_ref
from repro.kernels.moe_gemm.ops import grouped_ffn, moe_ffn
from repro.kernels.moe_gemm.ref import (grouped_ffn_bwd_ref, grouped_ffn_ref,
                                        moe_ffn_ref)
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.kd_loss.ops import ce_from_hidden, ce_kl_from_hidden
from repro.kernels.kd_loss.ref import ce_ref, ce_kl_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,KH,D", [
    (2, 64, 64, 4, 2, 32),
    (1, 128, 128, 2, 2, 64),
    (2, 33, 65, 3, 1, 16),
    (1, 256, 256, 8, 4, 8),
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 24, 0.0), (True, 0, 50.0), (False, 0, 0.0),
])
def test_flash_attention_matches_ref(B, Sq, Sk, H, KH, D, causal, window,
                                     softcap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KH, D))
    v = jax.random.normal(ks[2], (B, Sk, KH, D))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=32, block_k=32)
    kr = jnp.repeat(k, H // KH, 2)
    vr = jnp.repeat(v, H // KH, 2)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D),
        kr.transpose(0, 2, 1, 3).reshape(B * H, Sk, D),
        vr.transpose(0, 2, 1, 3).reshape(B * H, Sk, D),
        causal=causal, window=window, softcap=softcap,
    ).reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, H, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = attention_ref(q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                        k.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                        v.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                        causal=True).reshape(B, H, S, D).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 16, 30.0),
])
def test_flash_attention_grads_match_ref(causal, window, softcap):
    """jax.grad through the Pallas wrapper (custom VJP) vs. the oracle —
    guards the causal/window/softcap plumbing into the backward."""
    B, S, H, D = 1, 48, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))

    def loss_k(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=causal, window=window,
                                       softcap=softcap, block_q=16,
                                       block_k=16) ** 2)

    def loss_r(q, k, v):
        out = attention_ref(q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                            k.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                            v.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                            causal=causal, window=window, softcap=softcap)
        return jnp.sum(out ** 2)

    gk = jax.grad(loss_k, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, (0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# moe grouped FFN
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,D,F", [(4, 48, 32, 64), (2, 16, 16, 40),
                                     (8, 8, 64, 32)])
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_grouped_ffn_matches_ref(E, C, D, F, act):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (E, C, D))
    wg = jax.random.normal(ks[1], (E, D, F)) * 0.1
    wu = jax.random.normal(ks[2], (E, D, F)) * 0.1
    wo = jax.random.normal(ks[3], (E, F, D)) * 0.1
    out = grouped_ffn(x, wg, wu, wo, act=act, block_c=16, block_f=16)
    ref = grouped_ffn_ref(x, wg, wu, wo, act=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("T,D,F,E,k", [(40, 24, 32, 4, 2), (17, 16, 16, 3, 1)])
def test_routed_moe_matches_ref(T, D, F, E, k):
    ks = jax.random.split(KEY, 6)
    xt = jax.random.normal(ks[0], (T, D))
    logits = jax.random.normal(ks[1], (T, E))
    w, idx = jax.lax.top_k(jax.nn.softmax(logits), k)
    w = w / w.sum(-1, keepdims=True)
    wg = jax.random.normal(ks[2], (E, D, F)) * 0.1
    wu = jax.random.normal(ks[3], (E, D, F)) * 0.1
    wo = jax.random.normal(ks[4], (E, F, D)) * 0.1
    out = moe_ffn(xt, w, idx, wg, wu, wo)
    ref = moe_ffn_ref(xt, w, idx, wg, wu, wo)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_grouped_ffn_grads_match_ref(act):
    """Regression for the headline bug: jax.grad through the Pallas
    grouped FFN used to raise; now it must match the reference backward
    in all four inputs."""
    E, C, D, F = 3, 20, 16, 24
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (E, C, D))
    wg = jax.random.normal(ks[1], (E, D, F)) * 0.1
    wu = jax.random.normal(ks[2], (E, D, F)) * 0.1
    wo = jax.random.normal(ks[3], (E, F, D)) * 0.1
    dy = jax.random.normal(ks[4], (E, C, D))
    gk = jax.grad(lambda *a: jnp.sum(grouped_ffn(
        *a, act=act, block_c=16, block_f=16) * dy), (0, 1, 2, 3))(x, wg, wu, wo)
    gr = jax.grad(lambda *a: jnp.sum(grouped_ffn_ref(*a, act=act) * dy),
                  (0, 1, 2, 3))(x, wg, wu, wo)
    gb = grouped_ffn_bwd_ref(x, wg, wu, wo, dy, act=act)
    for a, b, c in zip(gk, gr, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-4)


def test_moe_ffn_grads_match_ref():
    """Gradients in tokens, router weights and all three expert weight
    tensors through the fused dispatch -> grouped FFN -> combine path."""
    T, D, F, E, k = 40, 24, 32, 4, 2
    ks = jax.random.split(KEY, 6)
    xt = jax.random.normal(ks[0], (T, D))
    logits = jax.random.normal(ks[1], (T, E))
    w, idx = jax.lax.top_k(jax.nn.softmax(logits), k)
    w = w / w.sum(-1, keepdims=True)
    wg = jax.random.normal(ks[2], (E, D, F)) * 0.1
    wu = jax.random.normal(ks[3], (E, D, F)) * 0.1
    wo = jax.random.normal(ks[4], (E, F, D)) * 0.1
    gk = jax.grad(lambda xt, w, wg, wu, wo: moe_ffn(
        xt, w, idx, wg, wu, wo).sum(), (0, 1, 2, 3, 4))(xt, w, wg, wu, wo)
    gr = jax.grad(lambda xt, w, wg, wu, wo: moe_ffn_ref(
        xt, w, idx, wg, wu, wo).sum(), (0, 1, 2, 3, 4))(xt, w, wg, wu, wo)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused token dispatch / combine
# ---------------------------------------------------------------------------

def test_gather_scatter_add_matches_ref():
    ks = jax.random.split(KEY, 4)
    src = jax.random.normal(ks[0], (13, 8))
    si = jax.random.randint(ks[1], (21,), 0, 13)
    di = jax.random.randint(ks[2], (21,), 0, 9)
    sc = jax.random.normal(ks[3], (21,))
    out = gather_scatter_add_rows(src, si, di, sc, 9, interpret=True)
    ref = gather_scatter_add_ref(src, si, di, sc, 9)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_token_dispatch_combine_kernel_matches_xla_and_grads():
    """The Pallas permute/unpermute and the pure-XLA fallback must agree
    in value and gradient — they define the MoE drop semantics once."""
    T, D, E, k, cap = 18, 12, 4, 2, 6
    ks = jax.random.split(KEY, 3)
    xt = jax.random.normal(ks[0], (T, D))
    flat_e = jax.random.randint(ks[1], (T * k,), 0, E)
    weights = jax.nn.softmax(jax.random.normal(ks[2], (T * k,)))
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    pos, keep = capacity_positions(flat_e, cap)
    assert int(jnp.max(jnp.where(keep, pos, 0))) < cap
    slot = flat_e * cap + pos

    def roundtrip(xt, weights, use_kernel):
        buf = token_dispatch(xt, flat_tok, slot, keep, E * cap,
                             use_kernel=use_kernel)
        return token_combine(buf, flat_tok, slot, keep, weights, T,
                             use_kernel=use_kernel)

    out_k = roundtrip(xt, weights, True)
    out_x = roundtrip(xt, weights, False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x),
                               rtol=1e-5, atol=1e-5)
    gk = jax.grad(lambda xt, w: roundtrip(xt, w, True).sum(), (0, 1))(
        xt, weights)
    gx = jax.grad(lambda xt, w: roundtrip(xt, w, False).sum(), (0, 1))(
        xt, weights)
    for a, b in zip(gk, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_use_pallas_moe_training_step_smoke():
    """End-to-end: one training step of an MoE model with use_pallas=True
    — expert FFN weights must receive nonzero, finite gradients."""
    from repro.models import model as M
    from repro.models.config import ModelConfig
    from repro.optim import adamw_init, adamw_update
    cfg = ModelConfig(name="moe-pallas-tiny", arch_type="moe", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                      d_ff=64, n_experts=4, top_k=2, moe_d_ff=64,
                      vocab_size=128, dtype="float32", remat=False,
                      attn_chunk_q=16, attn_chunk_k=16, loss_chunk=32,
                      use_pallas=True).validate()
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    (loss, _), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    for name in ("wi_gate", "wi_up", "wo"):
        g = grads["blocks"]["sub0"]["moe"][name]
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.linalg.norm(g)) > 0, f"zero grad for expert {name}"
    opt = adamw_init(params)
    new_params, _, stats = adamw_update(grads, opt, params, lr=1e-3)
    assert bool(jnp.isfinite(stats["grad_norm"]))
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 100, 3, 8, 16, 32),
    (1, 64, 2, 16, 8, 16),
    (1, 37, 1, 8, 8, 64),   # S < chunk, odd length
])
def test_ssd_kernel_matches_sequential_ref(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bh = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    Ch = jax.random.normal(ks[4], (B, S, H, N)) * 0.3
    y_k, h_k = ssd(xh, dt, A, Bh, Ch, chunk=chunk)
    xb = xh.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    y_r, h_r = ssd_ref(xb, dt.transpose(0, 2, 1).reshape(B * H, S),
                       jnp.tile(A, B),
                       Bh.transpose(0, 2, 1, 3).reshape(B * H, S, N),
                       Ch.transpose(0, 2, 1, 3).reshape(B * H, S, N))
    y_r = y_r.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k.reshape(B * H, P, N)),
                               np.asarray(h_r), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_carries_initial_state():
    B, S, H, P, N = 1, 32, 2, 8, 8
    ks = jax.random.split(KEY, 6)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bh = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    Ch = jax.random.normal(ks[4], (B, S, H, N)) * 0.3
    h0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.5
    y1, hf1 = ssd(xh, dt, A, Bh, Ch, chunk=8, init_state=h0)
    xb = xh.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    y2, hf2 = ssd_ref(xb, dt.transpose(0, 2, 1).reshape(B * H, S),
                      jnp.tile(A, B),
                      Bh.transpose(0, 2, 1, 3).reshape(B * H, S, N),
                      Ch.transpose(0, 2, 1, 3).reshape(B * H, S, N),
                      h0=h0.reshape(B * H, P, N))
    np.testing.assert_allclose(
        np.asarray(y1),
        np.asarray(y2.reshape(B, H, S, P).transpose(0, 2, 1, 3)),
        rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fused KD loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,Ds,Dt,V,tau,caps,capt,bv", [
    (16, 12, 8, 72, 2.0, 0.0, 0.0, 32),     # tau != 1, vocab pads
    (20, 8, 8, 96, 1.0, 30.0, 30.0, 32),    # both softcaps, no pad
    (12, 16, 8, 45, 4.0, 0.0, 50.0, 16),    # teacher-only cap, pad
])
def test_kd_loss_forward_and_grads(T, Ds, Dt, V, tau, caps, capt, bv):
    ks = jax.random.split(KEY, 5)
    hs = jax.random.normal(ks[0], (T, Ds))
    ws = jax.random.normal(ks[1], (Ds, V)) * 0.3
    ht = jax.random.normal(ks[2], (T, Dt))
    wt = jax.random.normal(ks[3], (Dt, V)) * 0.3
    lab = jax.random.randint(ks[4], (T,), 0, V)
    ce, kl, cor = ce_kl_from_hidden(hs, ws, ht, wt, lab, tau=tau,
                                    softcap_s=caps, softcap_t=capt,
                                    block_v=bv)
    ce_r, kl_r, cor_r = ce_kl_ref(hs, ws, ht, wt, lab, tau=tau,
                                  softcap_s=caps, softcap_t=capt)
    np.testing.assert_allclose(np.asarray(ce), np.asarray(ce_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kl), np.asarray(kl_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(cor), np.asarray(cor_r))

    def loss_k(hs, ws):
        ce, kl, _ = ce_kl_from_hidden(hs, ws, ht, wt, lab, tau=tau,
                                      softcap_s=caps, softcap_t=capt,
                                      block_v=bv)
        return jnp.mean(ce) + 0.7 * jnp.mean(kl)

    def loss_r(hs, ws):
        ce, kl, _ = ce_kl_ref(hs, ws, ht, wt, lab, tau=tau,
                              softcap_s=caps, softcap_t=capt)
        return jnp.mean(ce) + 0.7 * jnp.mean(kl)

    gk = jax.grad(loss_k, argnums=(0, 1))(hs, ws)
    gr = jax.grad(loss_r, argnums=(0, 1))(hs, ws)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_ce_only_path():
    T, D, V = 30, 12, 77
    ks = jax.random.split(KEY, 3)
    hs = jax.random.normal(ks[0], (T, D))
    ws = jax.random.normal(ks[1], (D, V)) * 0.3
    lab = jax.random.randint(ks[2], (T,), 0, V)
    ce, cor = ce_from_hidden(hs, ws, lab, block_v=16)
    ce_r, cor_r = ce_ref(hs, ws, lab)
    np.testing.assert_allclose(np.asarray(ce), np.asarray(ce_r),
                               rtol=1e-5, atol=1e-5)
    g1 = jax.grad(lambda h: jnp.sum(
        ce_from_hidden(h, ws, lab, block_v=16)[0]))(hs)
    g2 = jax.grad(lambda h: jnp.sum(ce_ref(h, ws, lab)[0]))(hs)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-5)


def test_model_layer_uses_pallas_consistently():
    """cfg.use_pallas=True must agree with the XLA path end-to-end."""
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("mamba2-1.3b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(7), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(8), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1, _ = M.loss_fn(params, cfg, batch)
    l2, _ = M.loss_fn(params, cfg.replace(use_pallas=True), batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


# ---------------------------------------------------------------------------
# paged attention: multi-query chunks (speculative verify / chunked prefill)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,C,H,KH,D,nb,bl,nbt,window,softcap", [
    (3, 4, 8, 4, 32, 10, 4, 6, 0, 0.0),    # GQA verify chunk
    (2, 3, 4, 4, 16, 8, 8, 3, 0, 30.0),    # MHA + softcap
    (2, 5, 8, 2, 32, 12, 4, 7, 6, 0.0),    # sliding window, C > window gap
    (1, 8, 4, 1, 16, 9, 4, 6, 0, 0.0),     # MQA, chunk wider than a block
])
def test_paged_attention_multi_query_matches_ref(B, C, H, KH, D, nb, bl,
                                                 nbt, window, softcap):
    """C>1 query chunks (contiguous positions pos..pos+C-1) against the
    gather oracle: per-query causal masks inside the chunk, blocks that
    straddle the chunk's first/last query, GQA grouping."""
    from repro.kernels.paged_attn.ops import paged_decode_attention
    from repro.kernels.paged_attn.ref import paged_attention_ref
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, C, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bl, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bl, KH, D)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, nb, size=(B, nbt)), jnp.int32)
    # last query must stay inside the table: pos + C - 1 <= nbt*bl - 1
    pos = jnp.asarray(rng.integers(0, nbt * bl - C + 1, size=(B,)), jnp.int32)
    ref = paged_attention_ref(q, kp, vp, bt, pos, window=window,
                              softcap=softcap)
    out = paged_decode_attention(q, kp, vp, bt, pos, window=window,
                                 softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_block_boundary_straddle():
    """A chunk whose queries straddle a block boundary: the first query's
    block is fully visible, the last query's block only partially — the
    per-query masks must not leak future positions."""
    from repro.kernels.paged_attn.ops import paged_decode_attention
    from repro.kernels.paged_attn.ref import paged_attention_ref
    rng = np.random.default_rng(2)
    B, C, H, KH, D, nb, bl, nbt = 2, 4, 4, 2, 16, 8, 4, 5
    q = jnp.asarray(rng.normal(size=(B, C, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bl, KH, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bl, KH, D)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, nb, size=(B, nbt)), jnp.int32)
    pos = jnp.asarray([bl - 2, 2 * bl - 1], jnp.int32)  # straddle two ways
    ref = paged_attention_ref(q, kp, vp, bt, pos)
    out = paged_decode_attention(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_read_path_multi_query_uses_pallas():
    """ISSUE 8: the C>1 gather fallback is retired — GQA chunks route
    through the kernel whenever cfg.use_pallas; MLA stays on gather."""
    from repro.configs import get_config
    from repro.models import layers
    gqa = get_config("tinyllama-1.1b", variant="reduced")
    mla = get_config("deepseek-v3-671b", variant="reduced")
    on = gqa.replace(use_pallas=True)
    assert layers.paged_read_path(on, 1) == "pallas"
    assert layers.paged_read_path(on, 4) == "pallas"
    assert layers.paged_read_path(gqa, 4) == "gather"        # use_pallas off
    assert layers.paged_read_path(mla.replace(use_pallas=True), 4,
                                  attn="mla") == "gather"    # MLA layout
