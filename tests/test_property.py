"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import clustering
from repro.kernels.kd_loss.ref import ce_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models import layers
from repro.models.ssm import ssd_chunked
from repro.optim import adamw_init, adamw_update
from repro.utils.pytree import tree_average

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# pytree / proxy averaging
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 5), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_tree_average_of_identical_trees_is_identity(n, seed):
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (3, 4)),
            "b": {"c": jax.random.normal(key, (5,))}}
    avg = tree_average([tree] * n)
    for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@given(seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_tree_average_is_permutation_invariant(seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    trees = [{"w": jax.random.normal(k, (4, 4))} for k in keys]
    a = tree_average(trees)
    b = tree_average(trees[::-1])
    # float summation order differs -> ULP-level tolerance
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# clustering
# ---------------------------------------------------------------------------

@given(n=st.integers(4, 20), k=st.integers(1, 5), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_kmeans_labels_valid_and_total(n, k, seed):
    rng = np.random.default_rng(seed)
    e = rng.standard_normal((n, 8)).astype(np.float32)
    labels, cents = clustering.spherical_kmeans(e, k, seed=seed)
    assert labels.shape == (n,)
    assert labels.min() >= 0 and labels.max() < min(k, n)


@given(seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_kmeans_scale_invariance(seed):
    """Cosine k-means must ignore embedding magnitudes."""
    rng = np.random.default_rng(seed)
    e = rng.standard_normal((10, 6)).astype(np.float32)
    scales = rng.uniform(0.1, 10.0, size=(10, 1)).astype(np.float32)
    l1, _ = clustering.spherical_kmeans(e, 3, seed=0)
    l2, _ = clustering.spherical_kmeans(e * scales, 3, seed=0)
    np.testing.assert_array_equal(l1, l2)


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------

@given(sq=st.integers(4, 40), dk=st.sampled_from([8, 16]),
       qc=st.sampled_from([4, 8, 16]), seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_chunked_attention_chunk_size_invariance(sq, dk, qc, seed):
    """Online-softmax result must not depend on the chunking."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    B, H = 1, 2
    q = jax.random.normal(ks[0], (B, sq, H, dk))
    k = jax.random.normal(ks[1], (B, sq, H, dk))
    v = jax.random.normal(ks[2], (B, sq, H, dk))
    pos = jnp.arange(sq)[None]
    a = layers.chunked_attention(q, k, v, pos, pos, causal=True,
                                 q_chunk=qc, k_chunk=qc)
    b = layers.chunked_attention(q, k, v, pos, pos, causal=True,
                                 q_chunk=sq, k_chunk=sq)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


@given(sq=st.integers(4, 24), seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_attention_rows_are_convex_combinations(sq, seed):
    """Causal attention output lies in the convex hull of V rows."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, 1, 8))
    k = jax.random.normal(ks[1], (1, sq, 1, 8))
    v = jax.random.normal(ks[2], (1, sq, 1, 8))
    pos = jnp.arange(sq)[None]
    out = layers.chunked_attention(q, k, v, pos, pos, causal=True,
                                   q_chunk=8, k_chunk=8)
    vmin = jnp.min(v, axis=1, keepdims=True)
    vmax = jnp.max(v, axis=1, keepdims=True)
    assert bool(jnp.all(out >= vmin - 1e-4))
    assert bool(jnp.all(out <= vmax + 1e-4))


@given(seed=st.integers(0, 30), cap=st.sampled_from([5.0, 30.0]))
@settings(**SETTINGS)
def test_softcap_bounds_scores(seed, cap):
    s = jax.random.normal(jax.random.PRNGKey(seed), (16,)) * 100
    c = layers._softcap(s, cap)
    assert bool(jnp.all(jnp.abs(c) <= cap + 1e-5))


# ---------------------------------------------------------------------------
# SSD invariants
# ---------------------------------------------------------------------------

@given(s=st.integers(8, 50), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_ssd_chunk_size_invariance(s, chunk, seed):
    B, H, P, N = 1, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    xh = jax.random.normal(ks[0], (B, s, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bh = jax.random.normal(ks[3], (B, s, H, N)) * 0.3
    Ch = jax.random.normal(ks[4], (B, s, H, N)) * 0.3
    y1, h1 = ssd_chunked(xh, dt, A, Bh, Ch, chunk=chunk)
    y2, h2 = ssd_chunked(xh, dt, A, Bh, Ch, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=3e-4, atol=3e-4)


@given(s1=st.integers(4, 20), s2=st.integers(4, 20), seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_ssd_state_chaining_matches_joint_scan(s1, s2, seed):
    """Running [0:s1] then [s1:s1+s2] with the carried state == one pass.
    This is the prefill->decode cache-consistency invariant."""
    B, H, P, N = 1, 1, 4, 4
    S = s1 + s2
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bh = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    Ch = jax.random.normal(ks[4], (B, S, H, N)) * 0.3
    y_full, h_full = ssd_chunked(xh, dt, A, Bh, Ch, chunk=8)
    y_a, h_a = ssd_chunked(xh[:, :s1], dt[:, :s1], A, Bh[:, :s1],
                           Ch[:, :s1], chunk=8)
    y_b, h_b = ssd_chunked(xh[:, s1:], dt[:, s1:], A, Bh[:, s1:],
                           Ch[:, s1:], chunk=8, init_state=h_a)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_full), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_full),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# optimizer invariants
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 50), lr=st.sampled_from([1e-3, 1e-2]))
@settings(**SETTINGS)
def test_adamw_frozen_leaves_never_move(seed, lr):
    key = jax.random.PRNGKey(seed)
    params = {"train": jax.random.normal(key, (4,)),
              "frozen": jax.random.normal(key, (4,))}
    mask = {"train": True, "frozen": False}
    opt = adamw_init(params, freeze_mask=mask)
    grads = {"train": jnp.ones(4), "frozen": jnp.ones(4)}
    new, opt, _ = adamw_update(grads, opt, params, lr=lr, freeze_mask=mask)
    np.testing.assert_array_equal(np.asarray(new["frozen"]),
                                  np.asarray(params["frozen"]))
    assert float(jnp.max(jnp.abs(new["train"] - params["train"]))) > 0


@given(seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_adamw_descends_quadratic(seed):
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros(8)}
    opt = adamw_init(params)
    loss0 = float(jnp.sum((params["w"] - target) ** 2))
    for _ in range(50):
        g = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(g, opt, params, lr=5e-2, clip_norm=0.0)
    assert float(jnp.sum((params["w"] - target) ** 2)) < loss0 * 0.5


# ---------------------------------------------------------------------------
# CE oracle invariants
# ---------------------------------------------------------------------------

@given(t=st.integers(2, 20), v=st.integers(3, 60), seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_ce_nonnegative_and_shift_invariant(t, v, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    hs = jax.random.normal(ks[0], (t, 8))
    ws = jax.random.normal(ks[1], (8, v)) * 0.5
    lab = jax.random.randint(ks[2], (t,), 0, v)
    ce, _ = ce_ref(hs, ws, lab)
    assert bool(jnp.all(ce >= -1e-5))
    # CE of uniform logits is log V
    ce_u, _ = ce_ref(jnp.zeros((t, 8)), jnp.zeros((8, v)), lab)
    np.testing.assert_allclose(np.asarray(ce_u), np.log(v), rtol=1e-5)
