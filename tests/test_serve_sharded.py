"""Mesh-native sharded serving tests (ISSUE 7).

Covers:
  * the 1-device degenerate decode mesh is bit-identical to
    ``mesh=None`` — caches, engines, completions;
  * the freed-slot capacity regression: a dead lane's garbage can
    never change a live slot's logits on a capacity-limited MoE mesh
    (and, as a negative control, DOES without the liveness mask);
  * ``sharding/rules.paged_cache_specs`` layouts under the abstract
    16x16 production mesh: pool blocks over "data", feature dims over
    "model", slot-resident state over "data", divisibility always;
  * the per-shard ``PagedAllocator``: contiguous id ownership,
    most-free placement, single-shard ordering unchanged;
  * the ``_overlap_ok`` gate and the ``hlo_analysis`` def-use overlap
    checker on synthetic HLO;
  * (>= 8 devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8)
    per-family sharded-vs-single-device token identity — greedy and
    temperature, contiguous and paged — EP-A2A overlap on/off identity,
    cache sharding persistence across admit/run, and a compiled-HLO
    overlap assertion on the real overlapped decode step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import hlo_analysis as H
from repro.launch.mesh import decode_mesh_shape, make_decode_mesh
from repro.models import model as M
from repro.models import moe
from repro.serve import PagedServeEngine, ServeEngine, Temperature
from repro.serve.paged import PagedAllocator
from repro.sharding import rules

from test_serve_chunked import ENGINE_ARCHS, family_batch, run_engine

MESH16 = rules.abstract_mesh((16, 16), ("data", "model"))

MULTI = len(jax.devices()) >= 8
needs_multi = pytest.mark.skipif(
    not MULTI, reason="needs >= 8 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def trivial_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# 1-device degenerate mesh == mesh=None (bitwise)
# ---------------------------------------------------------------------------

def test_decode_mesh_shapes():
    assert decode_mesh_shape(1) == (1, 1)
    assert decode_mesh_shape(2) == (1, 2)
    assert decode_mesh_shape(4) == (2, 2)
    assert decode_mesh_shape(8) == (2, 4)
    assert decode_mesh_shape(6) == (3, 2)  # odd residue stays on "data"
    assert dict(make_decode_mesh(1).shape) == {"data": 1, "model": 1}


def test_trivial_mesh_cache_init_identical():
    cfg = get_config("qwen2-moe-a2.7b", variant="reduced")
    a = M.init_decode_cache(cfg, 2, 16)
    b = M.init_decode_cache(cfg, 2, 16, mesh=trivial_mesh())
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    pa = M.init_paged_cache(cfg, 2, 8, 4)
    pb = M.init_paged_cache(cfg, 2, 8, 4, mesh=trivial_mesh())
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "mamba2-1.3b"])
def test_trivial_mesh_engine_bit_identical(arch):
    """ServeEngine on the 1-device degenerate decode mesh must emit the
    SAME tokens as mesh=None — same dense MoE path, no placement."""
    cfg = get_config(arch, variant="reduced").replace(overlap_a2a=True)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    lengths = [(6, 4), (9, 6)]
    batches = [family_batch(cfg, p, seed=20 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    ref, _ = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                        n_slots=2, seg_len=3, seed=0, mesh=None)
    mesh = trivial_mesh()
    with mesh:
        got, _ = run_engine(ServeEngine, params, cfg, batches, lengths,
                            max_len, n_slots=2, seg_len=3, seed=0, mesh=mesh)
    assert got == ref


# ---------------------------------------------------------------------------
# freed-slot capacity regression
# ---------------------------------------------------------------------------

def _capacity_rig():
    """A capacity-binding a2a MoE: 16 rows, identity-ish router (feature
    j -> expert j), 12 live rows all preferring expert 0, per-expert
    capacity 8 < 12 so drops are inevitable and rank order matters."""
    cfg = get_config("qwen2-moe-a2.7b", variant="reduced").replace(
        moe_impl="a2a", capacity_factor=0.25, n_shared_experts=0,
        router_aux_coef=0.0)
    E, D = cfg.n_experts, cfg.d_model
    F = cfg.moe_d_ff or cfg.d_ff
    router = np.zeros((D, E), np.float32)
    for e in range(E):
        router[e, e] = 10.0
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "router": jnp.asarray(router),
        "wi_gate": (jax.random.normal(ks[0], (E, D, F)) * 0.1).astype(dt),
        "wi_up": (jax.random.normal(ks[1], (E, D, F)) * 0.1).astype(dt),
        "wo": (jax.random.normal(ks[2], (E, F, D)) * 0.1).astype(dt),
    }
    B = 16
    x = np.zeros((B, 1, D), np.float32)
    x[4:, 0, 0] = 5.0                       # 12 live rows -> expert 0
    x[4:, 0, E:] = (np.arange(12)[:, None] + 1) * 0.01  # distinct outputs
    live = np.ones((B, 1), bool)
    live[:4] = False                        # rows 0..3 are freed slots
    return cfg, p, x, live


def _moe_out(cfg, p, x, garbage_experts, live, mesh):
    """apply_moe with rows 0..3 filled with (finite) garbage whose top-k
    routes to ``garbage_experts`` — (0, 1) contends with the live rows'
    choices, (2, 3) does not."""
    E = cfg.n_experts
    xg = x.copy()
    for ge in garbage_experts:
        xg[:4, 0, ge] = 5.0
    xg[:4, 0, E:] += 100.0                  # wild but finite garbage (the
    # identity router only reads features < E, so the routing preference
    # stays with ``garbage_experts``)
    with mesh:
        out, _ = moe.apply_moe(p, cfg, jnp.asarray(xg, cfg.dtype), mesh=mesh,
                               live=None if live is None
                               else jnp.asarray(live))
    return np.asarray(out)


def test_freed_slot_cannot_steal_capacity():
    """With the liveness mask, a freed slot's garbage routes nowhere: it
    holds no capacity rank and combines with weight 0, so live-slot
    outputs are BITWISE invariant to what the dead lane contains."""
    cfg, p, x, live = _capacity_rig()
    mesh = trivial_mesh()
    a = _moe_out(cfg, p, x, garbage_experts=(0, 1), live=live, mesh=mesh)
    b = _moe_out(cfg, p, x, garbage_experts=(2, 3), live=live, mesh=mesh)
    np.testing.assert_array_equal(a[4:], b[4:])
    assert np.all(np.isfinite(a))
    # dead rows combine with weight zero: their MoE output is exactly 0
    np.testing.assert_array_equal(a[:4], np.zeros_like(a[:4]))


def test_freed_slot_steals_capacity_without_mask():
    """Negative control: live=None (the pre-mask behavior) lets garbage
    rows occupy expert-0 capacity ranks ahead of live rows, changing
    which live assignments are dropped — live outputs diverge."""
    cfg, p, x, _ = _capacity_rig()
    mesh = trivial_mesh()
    a = _moe_out(cfg, p, x, garbage_experts=(0, 1), live=None, mesh=mesh)
    b = _moe_out(cfg, p, x, garbage_experts=(2, 3), live=None, mesh=mesh)
    assert np.any(a[4:] != b[4:])


# ---------------------------------------------------------------------------
# paged-pool sharding specs (abstract 16x16 production mesh)
# ---------------------------------------------------------------------------

def _paged_layout(arch, n_slots, n_blocks, block_len):
    cfg = get_config(arch, variant="reduced")
    cache = jax.eval_shape(
        lambda: M.init_paged_cache(cfg, n_slots, n_blocks, block_len))
    bax = M.decode_cache_batch_axes(cfg)
    sax = M.decode_cache_seq_axes(cfg)
    specs = rules.paged_cache_specs(cache, MESH16, batch_axes=bax,
                                    seq_axes=sax)
    flat = list(zip(jax.tree.leaves(cache),
                    jax.tree.leaves(specs,
                                    is_leaf=lambda s: isinstance(s, P)),
                    jax.tree.leaves(bax), jax.tree.leaves(sax)))
    return cfg, flat


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "deepseek-v3-671b",
                                  "mamba2-1.3b", "whisper-small"])
def test_paged_cache_specs_layouts(arch):
    n_data = MESH16.shape["data"]
    model = MESH16.shape["model"]
    cfg, flat = _paged_layout(arch, n_slots=16, n_blocks=64, block_len=8)
    saw_model = False
    for leaf, spec, bax, sax in flat:
        # pool/slot dim over "data" whenever divisible (n_blocks=64,
        # n_slots=16 both divide the 16-way data axis)
        if leaf.shape[bax] % n_data == 0:
            assert spec[bax] == "data", (leaf.shape, spec, bax)
        # pool leaves: trailing feature dim on "model" exactly when the
        # rule allows it; slot-resident leaves never shard on "model"
        last = leaf.ndim - 1
        if sax >= 0:
            expect = (last != bax and spec[last] != "data"
                      and leaf.shape[last] % model == 0
                      and leaf.shape[last] >= model)
            assert (spec[last] == "model") == expect, (leaf.shape, spec)
            saw_model |= spec[last] == "model"
        else:
            assert "model" not in tuple(spec), (leaf.shape, spec)
        # divisibility invariant: every assigned axis divides exactly
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= MESH16.shape[a]
            assert leaf.shape[dim] % n == 0, (leaf.shape, spec, dim)
    if arch in ("qwen2-moe-a2.7b", "deepseek-v3-671b"):
        assert saw_model  # KV heads x head_dim / MLA latent width shards


def test_paged_cache_specs_non_divisible_replicates():
    """A pool that doesn't divide the data axis replicates (never an
    error) — the engine likewise falls back to n_shards=1."""
    _, flat = _paged_layout("tinyllama-1.1b", n_slots=3, n_blocks=18,
                            block_len=4)
    for leaf, spec, bax, sax in flat:
        if leaf.shape[bax] in (3, 18):
            assert spec[bax] is None, (leaf.shape, spec)


# ---------------------------------------------------------------------------
# per-shard allocator
# ---------------------------------------------------------------------------

def test_allocator_shards_own_contiguous_ranges():
    al = PagedAllocator(8, 4, n_shards=2)
    assert [al.shard_of(b) for b in range(8)] == [0, 0, 0, 0, 1, 1, 1, 1]
    # trash block 0 lives in shard 0 and is never free
    assert 0 not in al.free_ids()
    assert al.n_free_shard(0) == 3 and al.n_free_shard(1) == 4
    assert al.n_free == 7 and al.n_live == 0


def test_allocator_balances_across_shards():
    al = PagedAllocator(8, 4, n_shards=2)
    # shard 1 has one more free block (no trash): first alloc comes from
    # it; ties then break to the lowest shard index
    seq = [al.alloc() for _ in range(7)]
    assert [al.shard_of(b) for b in seq] == [1, 0, 1, 0, 1, 0, 1]
    assert seq == [4, 1, 5, 2, 6, 3, 7]  # low ids first within a shard
    assert al.n_free == 0
    with pytest.raises(RuntimeError):
        al.alloc()
    al.release(6)
    assert al.n_free_shard(1) == 1 and al.n_free_shard(0) == 0
    assert al.shard_of(al.alloc()) == 1


def test_allocator_single_shard_order_unchanged():
    """n_shards=1 must hand out the exact id sequence of the pre-shard
    allocator: ascending ids, LIFO recycle."""
    al = PagedAllocator(6, 4)
    assert al.n_shards == 1
    assert [al.alloc() for _ in range(3)] == [1, 2, 3]
    al.release(2)
    assert al.alloc() == 2
    assert al.alloc() == 4


def test_allocator_rejects_bad_shard_split():
    with pytest.raises(ValueError):
        PagedAllocator(10, 4, n_shards=4)


def test_engine_trivial_mesh_keeps_single_shard_allocator():
    """n_data=1 meshes must not split the allocator (id order — and so
    block placement — stays identical to mesh=None)."""
    cfg = get_config("tinyllama-1.1b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    eng = PagedServeEngine(params, cfg, n_slots=2, max_len=16,
                           mesh=trivial_mesh(), block_len=4, n_blocks=8)
    assert eng.alloc.n_shards == 1


# ---------------------------------------------------------------------------
# overlap gate + HLO def-use checker
# ---------------------------------------------------------------------------

def test_overlap_ok_gate():
    moe_cfg = get_config("qwen2-moe-a2.7b",
                         variant="reduced").replace(overlap_a2a=True)
    dense_cfg = get_config("tinyllama-1.1b",
                           variant="reduced").replace(overlap_a2a=True)
    mesh = rules.abstract_mesh((2, 4), ("data", "model"))
    flat = rules.abstract_mesh((1, 8), ("data", "model"))
    one = rules.abstract_mesh((8, 1), ("data", "model"))
    assert M._overlap_ok(moe_cfg, mesh, 4, None)
    assert M._overlap_ok(moe_cfg, flat, 2, None)
    assert not M._overlap_ok(moe_cfg.replace(overlap_a2a=False), mesh, 4, None)
    assert not M._overlap_ok(dense_cfg, mesh, 4, None)          # not MoE
    assert not M._overlap_ok(moe_cfg, None, 4, None)            # no mesh
    assert not M._overlap_ok(moe_cfg, one, 4, None)             # model == 1
    assert not M._overlap_ok(moe_cfg, mesh, 3, None)            # odd batch
    assert not M._overlap_ok(moe_cfg, mesh, 0, None)            # empty
    assert not M._overlap_ok(moe_cfg, mesh, 4, object())        # paged
    assert not M._overlap_ok(moe_cfg.replace(moe_impl="replicated_ep"),
                             mesh, 4, None)


_HLO_INDEPENDENT = """
HloModule m

%ffn (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  ROOT %d = f32[8,8] dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (a: f32[8,8], b: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %b = f32[8,8] parameter(1)
  %a2a = f32[8,8] all-to-all(%a), replica_groups={{0,1}}
  %mm = f32[8,8] fusion(%b), kind=kLoop, calls=%ffn
  ROOT %r = f32[8,8] add(%a2a, %mm)
}
"""

_HLO_DEPENDENT = """
HloModule m

ENTRY %main (a: f32[8,8], b: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %b = f32[8,8] parameter(1)
  %a2a = f32[8,8] all-to-all(%a), replica_groups={{0,1}}
  ROOT %mm = f32[8,8] dot(%a2a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

_HLO_NO_A2A = """
HloModule m

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  ROOT %mm = f32[8,8] dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_hlo_overlap_independent_fusion_dot():
    pairs = H.a2a_overlap_pairs(_HLO_INDEPENDENT)
    assert [(c, a) for c, a, _ in pairs] == [("main", "a2a")]
    assert pairs[0][2] >= 1  # the %mm fusion (dot-bearing) is independent
    H.assert_a2a_overlap(_HLO_INDEPENDENT)


def test_hlo_overlap_dependent_dot_raises():
    pairs = H.a2a_overlap_pairs(_HLO_DEPENDENT)
    assert pairs == [("main", "a2a", 0)]  # the only dot consumes the a2a
    with pytest.raises(AssertionError):
        H.assert_a2a_overlap(_HLO_DEPENDENT)


def test_hlo_overlap_no_a2a_raises():
    with pytest.raises(AssertionError):
        H.assert_a2a_overlap(_HLO_NO_A2A)


# ---------------------------------------------------------------------------
# multi-device: sharded-vs-single token identity, overlap, placement
# ---------------------------------------------------------------------------

def _traffic(cfg, n=4):
    lengths = [(6, 4), (9, 6), (7, 5), (11, 3)][:n]
    batches = [family_batch(cfg, p, seed=10 + i)
               for i, (p, _) in enumerate(lengths)]
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    return batches, lengths, max_len


@needs_multi
@pytest.mark.parametrize("arch", ENGINE_ARCHS)
def test_sharded_engine_matches_single_device(arch):
    """The decode-mesh engine must emit token-identical completions to
    the single-device engine on every arch family (greedy)."""
    cfg = get_config(arch, variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batches, lengths, max_len = _traffic(cfg)
    ref, _ = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                        n_slots=2, seg_len=3, seed=0, mesh=None)
    mesh = make_decode_mesh()
    assert mesh.shape["model"] > 1
    with mesh:
        got, eng = run_engine(ServeEngine, params, cfg, batches, lengths,
                              max_len, n_slots=2, seg_len=3, seed=0,
                              mesh=mesh)
    assert got == ref
    # the cache layout survives admission grafts and the decode scan
    assert any(not l.sharding.is_fully_replicated
               for l in jax.tree.leaves(eng.cache))


@needs_multi
@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "tinyllama-1.1b"])
def test_sharded_paged_engine_matches_single_device(arch):
    cfg = get_config(arch, variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batches, lengths, max_len = _traffic(cfg)
    kw = dict(n_slots=2, seg_len=3, seed=0, block_len=4, n_blocks=32)
    ref, _ = run_engine(PagedServeEngine, params, cfg, batches, lengths,
                        max_len, mesh=None, **kw)
    mesh = make_decode_mesh()
    with mesh:
        got, eng = run_engine(PagedServeEngine, params, cfg, batches,
                              lengths, max_len, mesh=mesh, **kw)
    assert got == ref
    # 32 blocks / data axis -> per-shard free lists engaged
    assert eng.alloc.n_shards == mesh.shape["data"]
    assert eng.alloc.n_free == eng.alloc.n_blocks - 1  # drained


@needs_multi
def test_sharded_sampling_matches_single_device():
    """Temperature sampling: the per-request key protocol is mesh-blind,
    so stochastic completions match too."""
    cfg = get_config("qwen2-moe-a2.7b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batches, lengths, max_len = _traffic(cfg, n=3)
    kw = dict(n_slots=2, seg_len=3, seed=7, sampler=Temperature(0.8))
    ref, _ = run_engine(ServeEngine, params, cfg, batches, lengths, max_len,
                        mesh=None, **kw)
    mesh = make_decode_mesh()
    with mesh:
        got, _ = run_engine(ServeEngine, params, cfg, batches, lengths,
                            max_len, mesh=mesh, **kw)
    assert got == ref


@needs_multi
def test_overlap_a2a_token_identity():
    """cfg.overlap_a2a splits the decode batch in half around the EP
    all-to-all; at serving capacity (no drops) completions must be
    token-identical with the overlap off."""
    cfg = get_config("qwen2-moe-a2.7b", variant="reduced")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batches, lengths, max_len = _traffic(cfg)
    mesh = make_decode_mesh()
    with mesh:
        off, _ = run_engine(ServeEngine, params, cfg, batches, lengths,
                            max_len, n_slots=2, seg_len=3, seed=0, mesh=mesh)
        on, _ = run_engine(ServeEngine, params,
                           cfg.replace(overlap_a2a=True), batches, lengths,
                           max_len, n_slots=2, seg_len=3, seed=0, mesh=mesh)
    assert on == off


@needs_multi
def test_overlapped_decode_step_hlo_has_independent_a2a():
    """Compile the overlapped decode step on the real decode mesh and
    assert, at the HLO level, that an all-to-all has dataflow-independent
    matmul work to hide behind (the other half's attention/FFN)."""
    cfg = get_config("qwen2-moe-a2.7b",
                     variant="reduced").replace(overlap_a2a=True)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    mesh = make_decode_mesh()
    B = 2
    with mesh:
        cache = M.init_decode_cache(cfg, B, 16, mesh=mesh)
        toks = jnp.zeros((B, 1), jnp.int32)
        pos = jnp.asarray([3, 5], jnp.int32)
        live = jnp.ones((B,), jnp.bool_)
        assert M._overlap_ok(cfg, mesh, B, None)
        fn = jax.jit(lambda p, c, t, q, lv: M.decode_step(
            p, cfg, c, t, q, mesh=mesh, live=lv))
        txt = fn.lower(params, cache, toks, pos, live).compile().as_text()
    H.assert_a2a_overlap(txt)
