"""Builds the EXPERIMENTS.md §Roofline table from experiments/dryrun/*.json.

Adds MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (prefill/decode) and the
MODEL_FLOPS / HLO_FLOPS usefulness ratio.
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ALL, SHAPES, get_config
from repro.models import model as M
from repro.utils.pytree import tree_size

CHIPS = 256


def active_params(name):
    cfg = get_config(name)
    shapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    total = tree_size(shapes)
    if not cfg.is_moe:
        return total, total
    # routed expert tensors scale by top_k / n_experts when active
    import jax.tree_util as jtu
    from repro.utils.pytree import path_str
    flat, _ = jtu.tree_flatten_with_path(shapes)
    routed = sum(l.size for p, l in flat
                 if "moe/wi_gate" in path_str(p) or "moe/wi_up" in path_str(p)
                 or ("moe/wo" in path_str(p) and "shared" not in path_str(p)))
    active = total - routed + routed * cfg.top_k / cfg.n_experts
    return total, int(active)


def model_flops(name, shape_name):
    shp = SHAPES[shape_name]
    total, active = active_params(name)
    if shp.kind == "train":
        return 6 * active * shp.global_batch * shp.seq_len
    if shp.kind == "prefill":
        return 2 * active * shp.global_batch * shp.seq_len
    return 2 * active * shp.global_batch  # decode: one token per seq


def main():
    here = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")
    rows = []
    for f in sorted(glob.glob(os.path.join(here, "*_16x16.json"))):
        rec = json.load(open(f))
        arch, shape = rec["arch"], rec["shape"]
        if rec["status"] == "SKIP":
            rows.append((arch, shape, "SKIP", rec["reason"]))
            continue
        t = rec["roofline"]
        mf = model_flops(arch, shape)
        hlo_total = t["flops_per_device"] * CHIPS
        ratio = mf / hlo_total if hlo_total else 0.0
        peak = (rec["memory"]["peak_bytes"] or 0) / 2**30
        rows.append((arch, shape, "OK", dict(
            tc=t["t_compute_s"], tm=t["t_memory_s"], tx=t["t_collective_s"],
            dom=t["dominant"], ratio=ratio, peak=peak,
            mf=mf, hlo=hlo_total)))

    print("| arch | shape | t_compute | t_memory | t_collective | dominant |"
          " model/HLO flops | peak GiB/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for arch, shape, st, info in rows:
        if st == "SKIP":
            print(f"| {arch} | {shape} | — | — | — | SKIP | — | — "
                  f"({info}) |")
            continue
        print(f"| {arch} | {shape} | {info['tc']:.2e}s | {info['tm']:.2e}s "
              f"| {info['tx']:.2e}s | **{info['dom']}** "
              f"| {info['ratio']:.2f} | {info['peak']:.1f} |")


if __name__ == "__main__":
    main()
