"""Serving launcher: batched prefill + decode with a KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
      --variant reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M


def pad_cache_to(cache, prefill_caches):
    """Copy prefill cache entries (length S_p) into a larger decode cache.

    Exactly one dim (the sequence axis) may differ between the decode
    and prefill entries; anything else is a caller bug and raises.
    """
    def copy(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        diff = [ax for ax, (a, b) in enumerate(zip(dst.shape, src.shape))
                if a != b]
        if dst.ndim != src.ndim or len(diff) != 1:
            raise ValueError(
                f"pad_cache_to: decode cache {dst.shape} and prefill cache "
                f"{src.shape} differ in more than one dim — the caches were "
                f"built for different batch/model shapes")
        idx = [slice(None)] * dst.ndim
        idx[diff[0]] = slice(0, src.shape[diff[0]])
        return dst.at[tuple(idx)].set(src.astype(dst.dtype))

    return jax.tree.map(copy, cache, prefill_caches)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="reduced")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=512)
    args = ap.parse_args()

    cfg = get_config(args.arch, variant=args.variant)
    if args.variant == "reduced":
        cfg = cfg.replace(vocab_size=args.vocab)
    if cfg.arch_type == "encdec":
        raise SystemExit("use whisper decode via examples/serve_batched.py")
    mesh = make_host_mesh()
    B, P, G = args.batch, args.prompt_len, args.gen
    cap = P + G + 1

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)
    batch = {"tokens": prompt}
    if cfg.arch_type == "vlm":
        batch["patches"] = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.dtype(cfg.dtype))

    with mesh:
        t0 = time.time()
        logits, pc = jax.jit(lambda p, b: M.prefill(p, cfg, b))(params, batch)
        print(f"prefill: {B}x{P} in {time.time()-t0:.2f}s")
        cache = M.init_decode_cache(cfg, B, cap)
        # align prefill cache into the decode cache (attn-cache archs)
        if cfg.arch_type in ("dense", "moe", "vlm"):
            cache["blocks"] = pad_cache_to(cache["blocks"], pc["blocks"])
            if "dense_blocks" in pc:
                cache["dense_blocks"] = pad_cache_to(
                    cache["dense_blocks"], pc["dense_blocks"])
        elif cfg.arch_type == "ssm":
            cache = {"blocks": pc["blocks"]}
        step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        offset = cfg.frontend_tokens if cfg.arch_type == "vlm" else 0
        out_tokens = [tok]
        t0 = time.time()
        for i in range(G):
            pos = jnp.full((B,), offset + P + i, jnp.int32)
            logits, cache = step(params, cache, tok, pos)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        dt = time.time() - t0
        gen = jnp.concatenate(out_tokens, 1)
        print(f"decode: {G} steps x {B} batch in {dt:.2f}s "
              f"({B*G/dt:.1f} tok/s)")
        print("sample:", np.asarray(gen[0])[:16])


if __name__ == "__main__":
    main()
