"""Serving launcher: continuous-batching engine over every arch family.

Thin client of ``repro.serve.ServeEngine`` — prefill grafting, the
scanned decode loop and slot admission all live in the engine / model
layer.  All six families run, including encdec (whisper: stub audio
frames feed the encoder, the decoder prompt is served like any other).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
      --variant reduced --requests 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch whisper-small \
      --variant reduced --requests 3 --mixed
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve import (Greedy, PagedServeEngine, ServeEngine, Temperature,
                         TopK)


def mixed_lengths(n: int, prompt_len: int, gen: int):
    """Demo traffic: request i gets a shorter prompt + generation."""
    return [(max(4, prompt_len - 4 * i), max(2, gen - 3 * i))
            for i in range(n)]


def prompt_batch(cfg, rng, prompt_len: int):
    """A leading-dim-1 prefill batch for any arch family."""
    toks = rng.integers(0, cfg.vocab_size, (1, prompt_len))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    dt = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(1, cfg.frontend_tokens, cfg.d_model)) * 0.05, dt)
    if cfg.arch_type == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(1, cfg.frontend_tokens, cfg.d_model)) * 0.05, dt)
    return batch


def pick_sampler(args):
    if args.top_k:
        return TopK(args.top_k, args.temperature or 1.0)
    if args.temperature:
        return Temperature(args.temperature)
    return Greedy()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="reduced")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seg-len", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--mixed", action="store_true",
                    help="vary prompt/gen length per request")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="serve through the block-paged KV engine")
    ap.add_argument("--block-len", type=int, default=8,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--blocks", type=int, default=0,
                    help="paged engine: pool size (0 = worst-case default)")
    args = ap.parse_args()

    cfg = get_config(args.arch, variant=args.variant)
    if args.variant == "reduced":
        cfg = cfg.replace(vocab_size=args.vocab)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)

    P, G = args.prompt_len, args.gen
    if args.mixed:
        lengths = mixed_lengths(args.requests, P, G)
    else:
        lengths = [(P, G)] * args.requests
    # caches sized exactly: prompt + max_new (+ VLM patch offset), no +1
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    with mesh:
        if args.paged:
            engine = PagedServeEngine(
                params, cfg, n_slots=args.slots, max_len=max_len,
                sampler=pick_sampler(args), seg_len=args.seg_len, mesh=mesh,
                block_len=args.block_len,
                n_blocks=args.blocks or None)
        else:
            engine = ServeEngine(params, cfg, n_slots=args.slots,
                                 max_len=max_len, sampler=pick_sampler(args),
                                 seg_len=args.seg_len, mesh=mesh)
        for p, g in lengths:
            engine.submit(prompt_batch(cfg, rng, p), max_new=g)
        t0 = time.time()
        comps = engine.run()
        dt = time.time() - t0
    n_tok = engine.stats["generated_tokens"]
    util = (engine.stats["live_slot_steps"] / max(engine.stats["slot_steps"], 1))
    print(f"{args.arch}: {len(comps)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, {engine.stats['segments']} segments, "
          f"slot util {util:.0%})")
    if args.paged:
        print(f"paged: block_len={engine.block_len} pool={engine.n_blocks} "
              f"peak_blocks={engine.stats['peak_live_blocks']} "
              f"shared={engine.stats['shared_blocks']} "
              f"(free after drain: {engine.alloc.n_free})")
    first = comps[min(comps)]
    print("sample:", first.tokens[:16])


if __name__ == "__main__":
    main()
