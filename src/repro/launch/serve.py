"""Serving launcher: continuous-batching engine over every arch family.

Thin client of ``repro.serve.ServeEngine`` — prefill grafting, the
scanned decode loop and slot admission all live in the engine / model
layer.  All six families run, including encdec (whisper: stub audio
frames feed the encoder, the decoder prompt is served like any other).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
      --variant reduced --requests 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch whisper-small \
      --variant reduced --requests 3 --mixed
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_decode_mesh, make_host_mesh
from repro.models import model as M
from repro.models.layers import paged_read_path
from repro.serve import (Greedy, PagedServeEngine, ServeEngine, Temperature,
                         TopK)


def mixed_lengths(n: int, prompt_len: int, gen: int):
    """Demo traffic: request i gets a shorter prompt + generation."""
    return [(max(4, prompt_len - 4 * i), max(2, gen - 3 * i))
            for i in range(n)]


def prompt_batch(cfg, rng, prompt_len: int):
    """A leading-dim-1 prefill batch for any arch family."""
    toks = rng.integers(0, cfg.vocab_size, (1, prompt_len))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    dt = jnp.dtype(cfg.dtype)
    if cfg.arch_type == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(1, cfg.frontend_tokens, cfg.d_model)) * 0.05, dt)
    if cfg.arch_type == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(1, cfg.frontend_tokens, cfg.d_model)) * 0.05, dt)
    return batch


def pick_sampler(args):
    if args.top_k:
        return TopK(args.top_k, args.temperature or 1.0)
    if args.temperature:
        return Temperature(args.temperature)
    return Greedy()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="reduced")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seg-len", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--mixed", action="store_true",
                    help="vary prompt/gen length per request")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="serve through the block-paged KV engine")
    ap.add_argument("--block-len", type=int, default=8,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--blocks", type=int, default=0,
                    help="paged engine: pool size (0 = worst-case default)")
    ap.add_argument("--bucket", action="store_true",
                    help="bucketed chunked-prefill admission (compiles "
                         "O(#buckets) executables, not one per length)")
    ap.add_argument("--chunk-len", type=int, default=4,
                    help="bucketed admission: tokens per prefill chunk")
    ap.add_argument("--buckets", default="",
                    help="comma-separated bucket ladder (default: "
                         "powers-of-two chunk multiples)")
    ap.add_argument("--eager-blocks", action="store_true",
                    help="paged engine: reserve a request's worst-case "
                         "blocks at admission instead of lazily")
    ap.add_argument("--check-unbucketed", action="store_true",
                    help="replay the same traffic through an unbucketed "
                         "engine and fail unless completions match")
    ap.add_argument("--sharded", action="store_true",
                    help="serve on the decode mesh (data x model over every "
                         "visible device) instead of the flat host mesh")
    ap.add_argument("--overlap-a2a", action="store_true",
                    help="MoE decode: overlap the EP all-to-all with "
                         "attention compute (batch-level split)")
    ap.add_argument("--check-unsharded", action="store_true",
                    help="replay the same traffic single-device (mesh=None, "
                         "overlap off) and fail unless completions match")
    ap.add_argument("--speculate", action="store_true",
                    help="self-speculative MTP decode: draft + verify "
                         "n-draft tokens inside each compiled scan step "
                         "(needs an arch with an MTP head, cfg.n_mtp > 0)")
    ap.add_argument("--n-draft", type=int, default=3,
                    help="speculative decode: draft tokens per step")
    ap.add_argument("--check-unspeculated", action="store_true",
                    help="replay the same traffic without speculation and "
                         "fail unless completions match")
    ap.add_argument("--kv-dtype", default="",
                    choices=["", "fp32", "bf16", "fp8", "int8"],
                    help="KV-cache storage policy: int8/fp8 quantize pool "
                         "rows with per-position scales (repro.models.quant)")
    ap.add_argument("--check-unquantized", action="store_true",
                    help="replay the same traffic at full precision and "
                         "fail unless greedy completions match")
    args = ap.parse_args()
    if args.buckets and not args.bucket:
        ap.error("--buckets requires --bucket")
    if args.check_unbucketed and not args.bucket:
        ap.error("--check-unbucketed requires --bucket")
    if args.check_unsharded and not args.sharded:
        ap.error("--check-unsharded requires --sharded")
    if args.check_unspeculated and not args.speculate:
        ap.error("--check-unspeculated requires --speculate")
    if args.check_unquantized and args.kv_dtype not in ("int8", "fp8"):
        ap.error("--check-unquantized requires a quantized --kv-dtype")

    cfg = get_config(args.arch, variant=args.variant)
    if args.variant == "reduced":
        cfg = cfg.replace(vocab_size=args.vocab)
    if args.overlap_a2a:
        cfg = cfg.replace(overlap_a2a=True)
    mesh = make_decode_mesh() if args.sharded else make_host_mesh()
    rng = np.random.default_rng(0)

    P, G = args.prompt_len, args.gen
    if args.mixed:
        lengths = mixed_lengths(args.requests, P, G)
    else:
        lengths = [(P, G)] * args.requests
    # caches sized exactly: prompt + max_new (+ VLM patch offset), no +1
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    bucket_kw = {}
    if args.bucket:
        bucket_kw["chunk_len"] = args.chunk_len
        if args.buckets:
            bucket_kw["buckets"] = [int(b) for b in args.buckets.split(",")]
    if args.speculate:
        bucket_kw["speculate"] = args.n_draft  # rides every engine below
    if args.kv_dtype:
        bucket_kw["kv_dtype"] = args.kv_dtype
    with mesh:
        if args.paged:
            engine = PagedServeEngine(
                params, cfg, n_slots=args.slots, max_len=max_len,
                sampler=pick_sampler(args), seg_len=args.seg_len, mesh=mesh,
                block_len=args.block_len,
                n_blocks=args.blocks or None,
                lazy=not args.eager_blocks, **bucket_kw)
        else:
            engine = ServeEngine(params, cfg, n_slots=args.slots,
                                 max_len=max_len, sampler=pick_sampler(args),
                                 seg_len=args.seg_len, mesh=mesh, **bucket_kw)
        batches = [prompt_batch(cfg, rng, p) for p, _ in lengths]
        for b, (_, g) in zip(batches, lengths):
            engine.submit(b, max_new=g)
        t0 = time.time()
        comps = engine.run()
        dt = time.time() - t0
    n_tok = engine.stats["generated_tokens"]
    util = (engine.stats["live_slot_steps"] / max(engine.stats["slot_steps"], 1))
    print(f"{args.arch}: {len(comps)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, {engine.stats['segments']} segments, "
          f"slot util {util:.0%})")
    if args.bucket:
        print(f"bucketed: chunk_len={engine.chunk_len} "
              f"ladder={list(engine.buckets)} "
              f"compiles={engine.compiles_built}")
    if args.paged:
        print(f"paged: block_len={engine.block_len} pool={engine.n_blocks} "
              f"peak_blocks={engine.stats['peak_live_blocks']} "
              f"shared={engine.stats['shared_blocks']} "
              f"lazy_claimed={engine.stats['lazy_claimed_blocks']} "
              f"preemptions={engine.stats['preemptions']} "
              f"(free after drain: {engine.alloc.n_free}, "
              f"read path: {paged_read_path(cfg, 1)}, "
              f"allocator shards: {engine.alloc.n_shards})")
    if args.kv_dtype:
        cache_bytes = (M.paged_cache_nbytes(cfg, args.slots, engine.n_blocks,
                                            engine.block_len,
                                            policy=engine.policy)
                       if args.paged else
                       M.cache_nbytes(cfg, args.slots, max_len,
                                      policy=engine.policy))
        print(f"kv-dtype: {args.kv_dtype} cache_bytes={cache_bytes}")
    if args.sharded:
        print(f"sharded: mesh={dict(mesh.shape)} "
              f"overlap_a2a={cfg.overlap_a2a}")
    first = comps[min(comps)]
    print("sample:", first.tokens[:16])
    if args.speculate:
        print(f"speculative: n_draft={args.n_draft} "
              f"acceptance={engine.spec_acceptance():.1%} "
              f"({engine.stats['spec_extra_tokens']} extra tokens over "
              f"{engine.stats['spec_steps']} live steps)")
    if args.check_unbucketed:
        with mesh:
            ref = ServeEngine(params, cfg, n_slots=args.slots,
                              max_len=max_len, sampler=pick_sampler(args),
                              seg_len=args.seg_len, mesh=mesh)
            for b, (_, g) in zip(batches, lengths):
                ref.submit(b, max_new=g)
            ref_comps = ref.run()
        got = {u: c.tokens.tolist() for u, c in comps.items()}
        want = {u: c.tokens.tolist() for u, c in ref_comps.items()}
        if got != want:
            raise SystemExit(
                f"bucketed completions diverged from unbucketed: "
                f"{got} != {want}")
        print(f"check-unbucketed: completions match "
              f"({ref.compiles_built} reference compiles vs "
              f"{engine.compiles_built} bucketed)")
    if args.check_unsharded:
        ref_cfg = cfg.replace(overlap_a2a=False)
        if args.paged:
            ref = PagedServeEngine(
                params, ref_cfg, n_slots=args.slots, max_len=max_len,
                sampler=pick_sampler(args), seg_len=args.seg_len, mesh=None,
                block_len=args.block_len, n_blocks=args.blocks or None,
                lazy=not args.eager_blocks, **bucket_kw)
        else:
            ref = ServeEngine(params, ref_cfg, n_slots=args.slots,
                              max_len=max_len, sampler=pick_sampler(args),
                              seg_len=args.seg_len, mesh=None, **bucket_kw)
        for b, (_, g) in zip(batches, lengths):
            ref.submit(b, max_new=g)
        ref_comps = ref.run()
        got = {u: c.tokens.tolist() for u, c in comps.items()}
        want = {u: c.tokens.tolist() for u, c in ref_comps.items()}
        if got != want:
            raise SystemExit(
                f"sharded completions diverged from single-device: "
                f"{got} != {want}")
        print("check-unsharded: completions match")
    if args.check_unspeculated:
        plain_kw = {k: v for k, v in bucket_kw.items() if k != "speculate"}
        with mesh:
            if args.paged:
                ref = PagedServeEngine(
                    params, cfg, n_slots=args.slots, max_len=max_len,
                    sampler=pick_sampler(args), seg_len=args.seg_len,
                    mesh=mesh, block_len=args.block_len,
                    n_blocks=args.blocks or None,
                    lazy=not args.eager_blocks, **plain_kw)
            else:
                ref = ServeEngine(params, cfg, n_slots=args.slots,
                                  max_len=max_len,
                                  sampler=pick_sampler(args),
                                  seg_len=args.seg_len, mesh=mesh,
                                  **plain_kw)
            for b, (_, g) in zip(batches, lengths):
                ref.submit(b, max_new=g)
            t0 = time.time()
            ref_comps = ref.run()
            ref_dt = time.time() - t0
        got = {u: c.tokens.tolist() for u, c in comps.items()}
        want = {u: c.tokens.tolist() for u, c in ref_comps.items()}
        if got != want:
            raise SystemExit(
                f"speculative completions diverged from plain decode: "
                f"{got} != {want}")
        print(f"check-unspeculated: completions match "
              f"({engine.stats['segments']} speculative segments vs "
              f"{ref.stats['segments']} plain, replay {ref_dt:.2f}s)")
    if args.check_unquantized:
        fp_kw = {k: v for k, v in bucket_kw.items() if k != "kv_dtype"}
        with mesh:
            if args.paged:
                ref = PagedServeEngine(
                    params, cfg, n_slots=args.slots, max_len=max_len,
                    sampler=pick_sampler(args), seg_len=args.seg_len,
                    mesh=mesh, block_len=args.block_len,
                    n_blocks=args.blocks or None,
                    lazy=not args.eager_blocks, **fp_kw)
            else:
                ref = ServeEngine(params, cfg, n_slots=args.slots,
                                  max_len=max_len, sampler=pick_sampler(args),
                                  seg_len=args.seg_len, mesh=mesh, **fp_kw)
            for b, (_, g) in zip(batches, lengths):
                ref.submit(b, max_new=g)
            ref_comps = ref.run()
        got = {u: c.tokens.tolist() for u, c in comps.items()}
        want = {u: c.tokens.tolist() for u, c in ref_comps.items()}
        if got != want:
            raise SystemExit(
                f"{args.kv_dtype} completions diverged from full "
                f"precision: {got} != {want}")
        print(f"check-unquantized: {args.kv_dtype} completions match "
              f"full precision")


if __name__ == "__main__":
    main()
