"""Server-side distillation launcher: the DeepFusion pipeline as a CLI.

  PYTHONPATH=src python -m repro.launch.distill_run \
      --devices 8 --domains 4 --experts 4 --steps 40 [--method fedkmt]
"""
from __future__ import annotations

import argparse

from repro.federated.server import ServerConfig
from repro.federated.simulation import SimulationConfig, run_deepfusion
from repro.models.config import ModelConfig
from repro.checkpoint import save_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--domains", type=int, default=4)
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--steps", type=int, default=40,
                    help="device/distill/tune step budget")
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--method", default="deepfusion",
                    choices=["deepfusion", "fedkmt"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default="")
    args = ap.parse_args()

    small = dict(vocab_size=args.vocab, dtype="float32", remat=False,
                 attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32)
    dev_a = ModelConfig(name="gpt2-tiny", n_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=4, head_dim=16, d_ff=128,
                        norm_type="layernorm", act="gelu", mlp_gated=False,
                        pos_embedding="sinusoidal", **small).validate()
    dev_b = ModelConfig(name="llama-tiny", n_layers=3, d_model=96, n_heads=4,
                        n_kv_heads=2, head_dim=24, d_ff=192,
                        **small).validate()
    moe_cfg = ModelConfig(name="moe", arch_type="moe", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                          d_ff=128, n_experts=args.experts, top_k=2,
                          moe_d_ff=128, n_shared_experts=1,
                          **small).validate()
    sim = SimulationConfig(n_devices=args.devices, n_domains=args.domains,
                           vocab=args.vocab, seq_len=args.seq,
                           device_steps=args.steps, device_batch=8,
                           seed=args.seed)
    scfg = ServerConfig(moe_cfg=moe_cfg, distill_steps=args.steps,
                        distill_batch=8, tune_steps=args.steps, tune_batch=8,
                        seq_len=args.seq, n_stages=2, p_q=32, vaa_dim=64,
                        seed=args.seed,
                        alpha=0.0 if args.method == "fedkmt" else 1.0)
    params, report = run_deepfusion(sim, scfg, [dev_a, dev_b])
    m = report["metrics"]
    print(f"\n{args.method}: log-ppl {m['log_ppl']:.4f} "
          f"acc {m['accuracy']:.3f} comm {report['comm_bytes']/1e6:.1f} MB")
    if args.save:
        save_pytree(params, args.save)
        print("saved", args.save)


if __name__ == "__main__":
    main()
