"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
XLA_FLAGS before anything initialises the backend.

Production target: TPU v5e, 256 chips/pod.
  single pod : (data=16, model=16)
  multi-pod  : (pod=2, data=16, model=16) — "pod" extends the gradient
               all-reduce across the inter-pod (DCN-class) links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever the current host offers (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_fleet_mesh(n_hosts=None):
    """1-D ``("hosts",)`` mesh for multi-host bucketed fleet training.

    Each mesh entry stands for one simulation host; the fleet drivers
    shard the stacked device axis over it (``sharding.rules.fleet_specs``)
    so resident fleet state — and therefore fleet size — scales linearly
    with hosts.  CI exercises it with fake CPU devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
    """
    n = len(jax.devices()) if n_hosts is None else n_hosts
    if n > len(jax.devices()):
        raise ValueError(
            f"fleet mesh wants {n} hosts but only {len(jax.devices())} "
            "devices exist (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=N for fake hosts)")
    return jax.make_mesh((n,), ("hosts",))


def make_decode_mesh(n_devices=None):
    """(data, model) mesh shaped for serving decode.

    Decode roofline: at serving batch sizes every step streams the full
    weight + KV working set, so decode is HBM-bandwidth/ICI-bound, not
    FLOPs-bound — splitting weights over "model" multiplies effective
    HBM bandwidth (each chip streams 1/model of the weights per step,
    ~``HBM_BW * model`` aggregate), while the "data" axis only splits
    the (already small) batch.  So the model axis gets as many devices
    as possible: halve the device count into "model" until the data
    residue is odd.  8 devices -> (data=2, model=4); 4 -> (2, 2);
    2 -> (1, 2); 1 -> (1, 1) — the 1-device degenerate mesh is
    bit-identical to running with ``mesh=None``.  The model axis also
    carries the EP all-to-all and head sharding, both ICI-bound at
    ~``ICI_BW``; ``cfg.overlap_a2a`` hides that latency under attention
    compute.
    """
    d = len(jax.devices()) if n_devices is None else n_devices
    return jax.make_mesh(decode_mesh_shape(d), ("data", "model"))


def decode_mesh_shape(n_devices: int):
    """(data, model) split for ``make_decode_mesh`` — pure math, so the
    layout is testable without the devices to back it."""
    d, model = n_devices, 1
    while model < d and d % 2 == 0:
        model *= 2
        d //= 2
    return d, model


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (~ per chip, one direction)
HBM_BYTES = 16 * 1024 ** 3   # 16 GiB
