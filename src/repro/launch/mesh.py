"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
XLA_FLAGS before anything initialises the backend.

Production target: TPU v5e, 256 chips/pod.
  single pod : (data=16, model=16)
  multi-pod  : (pod=2, data=16, model=16) — "pod" extends the gradient
               all-reduce across the inter-pod (DCN-class) links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever the current host offers (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (~ per chip, one direction)
HBM_BYTES = 16 * 1024 ** 3   # 16 GiB
