"""Roofline terms from a lowered/compiled XLA module.

``cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
traffic — we parse the (post-SPMD, per-device) HLO text and sum the
output bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import math
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,2048,128]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind (per device, per step)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["total"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        # "%x = TYPE[...] op-name(...)" or tuple "( ... )"
        rhs = s.split("=", 1)[1]
        opm = re.search(r"\)?\s*([a-z0-9-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-gather-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting start/done pairs
        type_part = rhs[:opm.start()]
        nbytes = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(type_part))
        out[kind] += nbytes
        out["total"] += nbytes
    return out


def roofline_terms(cost: Dict, coll: Dict[str, int], *, peak_flops: float,
                   hbm_bw: float, ici_bw: float) -> Dict[str, float]:
    """All inputs are per-device.  Terms in seconds."""
    # clamp: two-point calibration slopes can go microscopically negative
    flops = max(float(cost.get("flops", 0.0)), 0.0)
    bytes_hbm = max(float(cost.get("bytes accessed", 0.0)), 0.0)
    bytes_coll = max(float(coll.get("total", 0)), 0.0)
    t_compute = flops / peak_flops
    t_memory = bytes_hbm / hbm_bw
    t_coll = bytes_coll / ici_bw
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": bytes_hbm,
        "collective_bytes_per_device": bytes_coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
    }
