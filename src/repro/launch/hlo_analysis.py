"""Roofline terms from a lowered/compiled XLA module.

``cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
traffic — we parse the (post-SPMD, per-device) HLO text and sum the
output bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.

The module also carries the EP-A2A overlap check
(``a2a_overlap_pairs`` / ``assert_a2a_overlap``): a def-use analysis
over the compiled HLO that proves an ``all-to-all`` has matmul work it
is dataflow-independent of — the structural precondition for XLA's
latency-hiding scheduler to actually run the collective concurrently
with compute (what ``cfg.overlap_a2a``'s half-batch split buys).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Set, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,2048,128]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind (per device, per step)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["total"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        # "%x = TYPE[...] op-name(...)" or tuple "( ... )"
        rhs = s.split("=", 1)[1]
        opm = re.search(r"\)?\s*([a-z0-9-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-gather-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting start/done pairs
        type_part = rhs[:opm.start()]
        nbytes = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(type_part))
        out[kind] += nbytes
        out["total"] += nbytes
    return out


# ---------------------------------------------------------------------------
# EP-A2A overlap: def-use independence of collectives vs matmul work
# ---------------------------------------------------------------------------

_OP_RE = re.compile(r"\)?\s*([a-z0-9-]+)\(")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.*)$")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*[({]")
_NAME_RE = re.compile(r"%?([\w.-]+)")


def _parse_computations(hlo_text: str):
    """HLO text -> {computation: [(name, op, operand_names, raw_rhs)]}.

    Tolerant line-based parse of both ``%name = ...`` and bare-name HLO
    dialects; operand extraction is conservative (any identifier in the
    rhs that is defined in the same computation counts as a dependency,
    so control/attribute references only ever ADD edges — the
    independence verdict can under-report, never over-report).
    """
    comps: Dict[str, List[Tuple[str, str, List[str], str]]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s:
            continue
        if s.endswith("{") and "=" not in s.split("(", 1)[0]:
            m = _HDR_RE.match(s)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(s)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        om = _OP_RE.search(rhs)
        if not om:
            continue
        comps[cur].append((name, om.group(1), [], rhs))
    # second pass: operands = identifiers defined in the same computation
    for cname, instrs in comps.items():
        defined = {n for n, _, _, _ in instrs}
        for entry in instrs:
            name, _, operands, rhs = entry
            for nm in _NAME_RE.findall(rhs):
                if nm in defined and nm != name:
                    operands.append(nm)
    return comps


def _dot_bearing(comps, cname: str) -> Set[str]:
    """Names of instructions in ``cname`` that carry matmul work: a
    ``dot``/``convolution``, a matmul custom-call, or a fusion/call whose
    called computation (transitively) contains one."""
    memo: Dict[str, bool] = {}

    def comp_has_dot(c: str) -> bool:
        if c not in comps:
            return False
        if c not in memo:
            memo[c] = False  # cycle guard
            memo[c] = any(_is_dot(op, rhs) for _, op, _, rhs in comps[c])
        return memo[c]

    def _is_dot(op: str, rhs: str) -> bool:
        if op in ("dot", "convolution"):
            return True
        if op == "custom-call" and ("gemm" in rhs or "matmul" in rhs
                                    or "dot" in rhs):
            return True
        if op in ("fusion", "call", "async-start"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.-]+)", rhs)
            return bool(m) and comp_has_dot(m.group(1))
        return False

    return {name for name, op, _, rhs in comps.get(cname, ())
            if _is_dot(op, rhs)}


def _closure(start: str, edges) -> Set[str]:
    out, todo = set(), [start]
    while todo:
        n = todo.pop()
        for nxt in edges(n):
            if nxt not in out:
                out.add(nxt)
                todo.append(nxt)
    return out


def a2a_overlap_pairs(hlo_text: str):
    """Per ``all-to-all``: how much matmul work it could overlap with.

    Returns [(computation, a2a_name, n_independent_dots)] — a
    dot-bearing instruction is *independent* of the collective when it
    is neither an ancestor nor a descendant in the computation's def-use
    graph, i.e. nothing forces it to run before or after, so the
    scheduler is free to run them concurrently.  ``-done`` halves of
    async pairs are skipped (their ``-start`` carries the dependencies).
    """
    comps = _parse_computations(hlo_text)
    results = []
    for cname, instrs in comps.items():
        ops = {name: operands for name, _, operands, _ in instrs}
        users = defaultdict(set)
        for name, _, operands, _ in instrs:
            for o in operands:
                users[o].add(name)
        dots = _dot_bearing(comps, cname)
        for name, op, _, _ in instrs:
            if not op.startswith("all-to-all") or op.endswith("-done"):
                continue
            anc = _closure(name, lambda n: ops.get(n, ()))
            desc = _closure(name, lambda n: users[n])
            results.append((cname, name, len(dots - anc - desc)))
    return results


def assert_a2a_overlap(hlo_text: str) -> None:
    """Raise unless some ``all-to-all`` has dataflow-independent matmul
    work available to overlap with (the ``cfg.overlap_a2a`` guarantee)."""
    pairs = a2a_overlap_pairs(hlo_text)
    if not pairs:
        raise AssertionError("no all-to-all instruction in the module — "
                             "is the MoE a2a path actually sharded?")
    if not any(n > 0 for _, _, n in pairs):
        raise AssertionError(
            "no all-to-all has dataflow-independent matmul work; the "
            "collective cannot overlap compute: "
            + ", ".join(f"{c}/{a}" for c, a, _ in pairs[:8]))


def roofline_terms(cost: Dict, coll: Dict[str, int], *, peak_flops: float,
                   hbm_bw: float, ici_bw: float) -> Dict[str, float]:
    """All inputs are per-device.  Terms in seconds."""
    # clamp: two-point calibration slopes can go microscopically negative
    flops = max(float(cost.get("flops", 0.0)), 0.0)
    bytes_hbm = max(float(cost.get("bytes accessed", 0.0)), 0.0)
    bytes_coll = max(float(coll.get("total", 0)), 0.0)
    t_compute = flops / peak_flops
    t_memory = bytes_hbm / hbm_bw
    t_coll = bytes_coll / ici_bw
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": bytes_hbm,
        "collective_bytes_per_device": bytes_coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
    }
