import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any other import (jax locks the
# device count at backend init).  512 placeholder host devices let
# jax.make_mesh build the production (2, 16, 16) mesh on this CPU-only
# container; nothing is ever executed — only lower() + compile().

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, compiles, fits, and report its roofline inputs.

For each combination this script:
  1. builds abstract params / optimizer state / caches (eval_shape —
     no allocation),
  2. jit-lowers the right step function (train_step / prefill_step /
     serve_step) with production in/out shardings,
  3. compiles, prints ``memory_analysis()`` (proves the memory layout
     fits) and ``cost_analysis()`` (FLOPs / bytes for §Roofline),
  4. parses collective bytes out of the compiled HLO,
  5. appends a JSON record under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALL, ASSIGNED, SHAPES, get_config, supported
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update
from repro.sharding import (batch_spec, cache_specs, named, opt_state_specs,
                            param_specs)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# Big configs keep Adam moments in bf16 (HBM headroom; EXPERIMENTS §Dry-run).
OPT_STATE_DTYPE = {"deepseek-v3-671b": jnp.bfloat16}


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    f = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    if shp.kind == "train":
        if cfg.arch_type == "vlm":
            s_txt = S - cfg.frontend_tokens
            return {"tokens": sds((B, s_txt), i32),
                    "labels": sds((B, s_txt), i32),
                    "patches": sds((B, cfg.frontend_tokens, cfg.d_model), f)}
        if cfg.arch_type == "encdec":
            return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32),
                    "frames": sds((B, cfg.frontend_tokens, cfg.d_model), f)}
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}

    if shp.kind == "prefill":
        if cfg.arch_type == "vlm":
            s_txt = S - cfg.frontend_tokens
            return {"tokens": sds((B, s_txt), i32),
                    "patches": sds((B, cfg.frontend_tokens, cfg.d_model), f)}
        if cfg.arch_type == "encdec":
            return {"tokens": sds((B, S), i32),
                    "frames": sds((B, cfg.frontend_tokens, cfg.d_model), f)}
        return {"tokens": sds((B, S), i32)}

    # decode: ONE new token against a seq_len-deep cache
    cache = jax.eval_shape(
        functools.partial(M.init_decode_cache, cfg, B, S))
    return {"cache": cache,
            "tokens": sds((B, 1), i32),
            "pos": sds((B,), i32)}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh, n_micro: int = 1):
    """``n_micro > 1``: gradient accumulation over micro-batches — the
    per-step activation footprint scales 1/n_micro at the cost of a
    params-sized f32 accumulator (§Perf iteration Z5)."""
    def grad_of(params, batch):
        return jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch, mesh=mesh), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.sharding import data_axes_of
            daxes = data_axes_of(mesh)
            dax = daxes if len(daxes) > 1 else daxes[0]

            def split(x):
                y = x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
                # keep "data" on the inner batch dim, NOT the scan dim
                spec = [None, dax] + [None] * (y.ndim - 2)
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P(*spec)))

            mb = jax.tree.map(split, batch)

            def micro(carry, b):
                gsum, loss_sum, acc_sum = carry
                (loss, metrics), g = grad_of(params, b)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                return (gsum, loss_sum + loss,
                        acc_sum + metrics["accuracy"]), 0

            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum, acc_sum), _ = jax.lax.scan(
                micro, (gz, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = loss_sum / n_micro
            metrics = {"accuracy": acc_sum / n_micro}
        params, opt_state, stats = adamw_update(
            grads, opt_state, params, lr=1e-4, weight_decay=0.01)
        return params, opt_state, loss, metrics["accuracy"]
    return train_step


def make_prefill_step(cfg: ModelConfig, mesh):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch, mesh=mesh)
    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh):
    def serve_step(params, cache, tokens, pos):
        return M.decode_step(params, cfg, cache, tokens, pos, mesh=mesh)
    return serve_step


# ---------------------------------------------------------------------------
# cost calibration (see ModelConfig.scan_unroll): XLA's cost_analysis
# counts while-loop bodies once, so the production (scanned) module
# under-reports FLOPs/bytes by ~n_layers.  We lower two UNROLLED
# reduced-depth variants (compile-only; memory is irrelevant), fit
# cost = intercept + slope * n_stack_units, and extrapolate to full depth.
# ---------------------------------------------------------------------------

def _cal_chunks(cfg: ModelConfig, shape_name: str):
    """Unrolled-calibration chunk sizes.  Long prefills coarsen the
    attention chunking (compile-time); short sequences keep the
    PRODUCTION chunking so chunk-granular optimisations (e.g. causal
    chunk skipping, §Perf Q1) are visible in the calibrated costs."""
    seq = SHAPES[shape_name].seq_len
    if SHAPES[shape_name].kind == "decode" or seq <= 8192:
        aq, ak = cfg.attn_chunk_q, cfg.attn_chunk_k
    else:
        aq = ak = 4096
    return dict(scan_unroll=True, attn_chunk_q=aq, attn_chunk_k=ak,
                loss_chunk=4096)


def calibration_points(cfg: ModelConfig, shape_name: str = "prefill_32k"):
    """(cfg_a, n_a, cfg_b, n_b, n_full) — n counts main-stack scan units;
    everything that does not scale with depth (embed, head, MTP, whisper
    encoder, zamba tail) lands in the intercept."""
    at = cfg.arch_type
    CAL = _cal_chunks(cfg, shape_name)
    if at in ("dense", "moe", "vlm"):
        lps = cfg.layers_per_scan
        fd = cfg.first_dense_layers
        n_full = (cfg.n_layers - fd) // lps
        return (cfg.replace(n_layers=fd + lps, **CAL), 1,
                cfg.replace(n_layers=fd + 2 * lps, **CAL), 2, n_full)
    if at == "ssm":
        return (cfg.replace(n_layers=1, **CAL), 1,
                cfg.replace(n_layers=2, **CAL), 2, cfg.n_layers)
    if at == "hybrid":
        period = cfg.shared_attn_every
        n_groups, tail = divmod(cfg.n_layers, period)
        return (cfg.replace(n_layers=period + tail, **CAL), 1,
                cfg.replace(n_layers=2 * period + tail, **CAL), 2,
                n_groups)
    if at == "encdec":
        return (cfg.replace(n_layers=1, **CAL), 1,
                cfg.replace(n_layers=2, **CAL), 2, cfg.n_layers)
    raise ValueError(at)


def _lower_combo(cfg: ModelConfig, shape_name: str, mesh, *, fsdp: bool,
                 serve_opt: bool = False):
    """Build + lower the right step for (cfg, shape).  Returns lowered.

    ``serve_opt``: the beyond-paper serving layout (EXPERIMENTS §Perf):
    weights resident (no FSDP gathers per decode step) and MoE experts
    sharded one-per-device over the whole mesh (``replicated_ep``)."""
    shp = SHAPES[shape_name]
    if serve_opt and shp.kind == "decode":
        if cfg.is_moe:
            cfg = cfg.replace(moe_impl="replicated_ep")
        p_fsdp, ep_all = False, True
    else:
        p_fsdp, ep_all = fsdp, False
    abstract_params = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    pshard = named(mesh, param_specs(abstract_params, mesh, fsdp=p_fsdp,
                                     ep_all=ep_all))
    batch = input_specs(cfg, shape_name)
    with mesh:
        if shp.kind == "train":
            n_micro = getattr(cfg, "_n_micro", 1)
            opt_dtype = OPT_STATE_DTYPE.get(cfg.name)
            abstract_opt = jax.eval_shape(
                functools.partial(adamw_init, state_dtype=opt_dtype),
                abstract_params)
            ospecs = opt_state_specs(abstract_params, mesh, fsdp=fsdp)
            oshard = {"m": named(mesh, ospecs["m"]),
                      "v": named(mesh, ospecs["v"]),
                      "step": named(mesh, ospecs["step"])}
            bshard = named(mesh, batch_spec(batch, mesh))
            fn = make_train_step(cfg, mesh, n_micro=n_micro)
            return jax.jit(fn, in_shardings=(pshard, oshard, bshard)).lower(
                abstract_params, abstract_opt, batch)
        if shp.kind == "prefill":
            bshard = named(mesh, batch_spec(batch, mesh))
            fn = make_prefill_step(cfg, mesh)
            return jax.jit(fn, in_shardings=(pshard, bshard)).lower(
                abstract_params, batch)
        cshard = named(mesh, cache_specs(batch["cache"], mesh,
                                         batch=shp.global_batch,
                                         seq=shp.seq_len))
        tshard = named(mesh, batch_spec(
            {"tokens": batch["tokens"], "pos": batch["pos"]}, mesh))
        fn = make_serve_step(cfg, mesh)
        return jax.jit(fn, in_shardings=(
            pshard, cshard, tshard["tokens"], tshard["pos"])).lower(
            abstract_params, batch["cache"], batch["tokens"], batch["pos"])


def _cost_of(lowered):
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll.get("total", 0)),
            "coll_by_kind": coll}


def calibrated_cost(cfg: ModelConfig, shape_name: str, mesh, *, fsdp: bool,
                    serve_opt: bool = False, verbose: bool = False):
    cfg_a, n_a, cfg_b, n_b, n_full = calibration_points(cfg, shape_name)
    t0 = time.time()
    ca = _cost_of(_lower_combo(cfg_a, shape_name, mesh, fsdp=fsdp,
                               serve_opt=serve_opt))
    cb = _cost_of(_lower_combo(cfg_b, shape_name, mesh, fsdp=fsdp,
                               serve_opt=serve_opt))
    out = {}
    for k in ("flops", "bytes", "coll"):
        slope = (cb[k] - ca[k]) / (n_b - n_a)
        out[k] = ca[k] + (n_full - n_a) * slope
        out[k + "_slope_per_unit"] = slope
    out["n_stack_units"] = n_full
    out["cal_seconds"] = round(time.time() - t0, 1)
    if verbose:
        print(f"  calibration: flops {ca['flops']:.3e}/{cb['flops']:.3e} "
              f"-> {out['flops']:.3e} ({out['cal_seconds']}s)")
    return out


# ---------------------------------------------------------------------------
# the dry run
# ---------------------------------------------------------------------------

def dry_run(arch: str, shape_name: str, *, multi_pod: bool = False,
            fsdp: bool = True, verbose: bool = True, calibrate: bool = True,
            serve_opt: bool = False, n_micro: int = 1,
            cfg_overrides=None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    if n_micro > 1:
        object.__setattr__(cfg, "_n_micro", n_micro)
    shp = SHAPES[shape_name]
    ok, why = supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "SKIP",
                "mesh": "2x16x16" if multi_pod else "16x16", "reason": why}

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "kind": shp.kind, "serve_opt": serve_opt}
    with mesh:
        lowered = _lower_combo(cfg, shape_name, mesh, fsdp=fsdp,
                               serve_opt=serve_opt)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    if calibrate:
        cal = calibrated_cost(cfg, shape_name, mesh, fsdp=fsdp,
                              serve_opt=serve_opt, verbose=verbose)
        eff_cost = {"flops": cal["flops"], "bytes accessed": cal["bytes"]}
        eff_coll = {"total": cal["coll"]}
    else:
        cal = None
        eff_cost, eff_coll = cost, coll
    terms = roofline_terms(eff_cost, eff_coll,
                           peak_flops=mesh_lib.PEAK_FLOPS_BF16,
                           hbm_bw=mesh_lib.HBM_BW, ici_bw=mesh_lib.ICI_BW)
    record.update({
        "status": "OK",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
                          + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "cost_raw_scanned": {k: cost.get(k) for k in
                             ("flops", "bytes accessed") if k in cost},
        "cost_calibrated": cal,
        "collectives_raw_scanned": coll,
        "roofline": terms,
        "hlo_collective_count": sum(
            1 for line in hlo.splitlines()
            if any(c in line for c in ("all-gather(", "all-reduce(",
                                       "reduce-scatter(", "all-to-all(",
                                       "collective-permute("))),
    })
    if verbose:
        hbm_gib = record["memory"]["peak_bytes"] / 2**30 \
            if record["memory"]["peak_bytes"] else -1
        print(f"[{arch} x {shape_name} x {record['mesh']}] OK "
              f"compile={t_compile:.1f}s peak/dev={hbm_gib:.2f}GiB "
              f"flops/dev={terms['flops_per_device']:.3e} "
              f"coll/dev={terms['collective_bytes_per_device']:.3e}B "
              f"dominant={terms['dominant']}")
        print("  memory_analysis:", record["memory"])
        print("  cost_analysis (calibrated):",
              {"flops": terms["flops_per_device"],
               "bytes": terms["hbm_bytes_per_device"],
               "collective_bytes": terms["collective_bytes_per_device"]})
    return record


def save_record(record: dict, tag: str = ""):
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{record['arch']}_{record['shape']}_{record['mesh']}{tag}.json"
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ALL))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all assigned arch x shape combos")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--serve-opt", action="store_true",
                    help="beyond-paper serving layout for decode shapes")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (repeatable)")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches for train")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in sorted(ASSIGNED):
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        path = os.path.join(OUT_DIR, f"{arch}_{shape}_{mesh_tag}{args.tag}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[{arch} x {shape} x {mesh_tag}] cached, skipping")
            continue
        try:
            overrides = {}
            for ov in args.override:
                k, v = ov.split("=", 1)
                try:
                    v = eval(v, {}, {})
                except Exception:
                    pass
                overrides[k] = v
            rec = dry_run(arch, shape, multi_pod=args.multi_pod,
                          fsdp=not args.no_fsdp,
                          calibrate=not args.no_calibrate,
                          serve_opt=args.serve_opt, n_micro=args.microbatch,
                          cfg_overrides=overrides or None)
            if rec["status"] == "SKIP":
                print(f"[{arch} x {shape}] SKIP: {rec['reason']}")
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "mesh": mesh_tag, "status": "FAIL", "error": str(e)[:2000]}
            failures.append((arch, shape))
        save_record(rec, args.tag)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
