"""Training launcher: pjit data+tensor+expert-parallel LM training.

On real hardware this drives the production mesh; on this container it
runs reduced configs on the host mesh.  The same step function is what
the dry-run lowers for the full configs.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --variant reduced --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.federated import FederatedCorpus
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.sharding import batch_spec, named, opt_state_specs, param_specs
from repro.checkpoint import save_pytree


def make_batch(cfg, corpus, step, batch, seq):
    b = corpus.mixed_eval_batch(batch, seq, seed_salt=step)
    if cfg.arch_type == "vlm":
        b["patches"] = jnp.zeros((batch, cfg.frontend_tokens, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    if cfg.arch_type == "encdec":
        b["frames"] = jnp.zeros((batch, cfg.frontend_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    return b


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="reduced",
                    choices=["full", "reduced"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--save", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, variant=args.variant)
    if args.variant == "reduced":
        cfg = cfg.replace(vocab_size=args.vocab)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    corpus = FederatedCorpus.build(seed=0, n_devices=4, n_domains=4,
                                   vocab=cfg.vocab_size)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    pshard = named(mesh, param_specs(params, mesh))
    oshard = {"m": named(mesh, param_specs(params, mesh)),
              "v": named(mesh, param_specs(params, mesh)),
              "step": named(mesh, opt_state_specs(params, mesh)["step"])}
    params = jax.device_put(params, pshard)
    sched = cosine_schedule(args.lr, args.steps, warmup=max(args.steps // 20, 1))

    def step_fn(params, opt, batch, lr):
        (loss, metrics), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch, mesh=mesh), has_aux=True)(params)
        params, opt, stats = adamw_update(g, opt, params, lr=lr,
                                          weight_decay=0.01)
        return params, opt, loss, metrics["accuracy"], stats["grad_norm"]

    with mesh:
        jitted = jax.jit(step_fn)
        t0 = time.time()
        for s in range(args.steps):
            batch = make_batch(cfg, corpus, s, args.batch, args.seq)
            params, opt, loss, acc, gn = jitted(params, opt, batch, sched(s))
            if s % max(args.steps // 10, 1) == 0 or s == args.steps - 1:
                print(f"step {s:4d} loss {float(loss):.4f} "
                      f"acc {float(acc):.3f} gnorm {float(gn):.2e} "
                      f"({time.time()-t0:.1f}s)")
    if args.save:
        save_pytree(params, args.save)
        print("saved", args.save)


if __name__ == "__main__":
    main()
