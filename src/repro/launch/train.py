"""Training launcher: pjit data+tensor+expert-parallel LM training.

On real hardware this drives the production mesh; on this container it
runs reduced configs on the host mesh.  The same step function is what
the dry-run lowers for the full configs.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --variant reduced --steps 20 --batch 8 --seq 128

Fleet mode (``--fleet N``) instead drives the federated device fleet —
synchronous one-shot by default, async participation rounds with
``--async-rounds`` — and is what CI's fleet-smoke job exercises under
fake hosts:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.train --fleet 16 --n-hosts 4 \
      --async-rounds 3 --steps-per-round 4 --dropout 0.25 \
      --deadline-policy stale --straggler-profile mild
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.federated import FederatedCorpus
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.sharding import batch_spec, named, opt_state_specs, param_specs
from repro.checkpoint import save_pytree


def make_batch(cfg, corpus, step, batch, seq):
    b = corpus.mixed_eval_batch(batch, seq, seed_salt=step)
    if cfg.arch_type == "vlm":
        b["patches"] = jnp.zeros((batch, cfg.frontend_tokens, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    if cfg.arch_type == "encdec":
        b["frames"] = jnp.zeros((batch, cfg.frontend_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    return b


# tiny stand-ins for two device families, sized so the fleet smoke runs
# in seconds on CPU (the real families live in benchmarks/common.py —
# src never imports from benchmarks)
_FLEET_TINY = dict(vocab_size=256, dtype="float32", remat=False,
                   attn_chunk_q=16, attn_chunk_k=16, loss_chunk=16)


def _fleet_families():
    return [
        ModelConfig(name="fleet-gpt2-tiny", n_layers=2, d_model=32,
                    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                    norm_type="layernorm", act="gelu", mlp_gated=False,
                    pos_embedding="sinusoidal", **_FLEET_TINY).validate(),
        ModelConfig(name="fleet-llama-tiny", n_layers=2, d_model=48,
                    n_heads=2, n_kv_heads=2, head_dim=24, d_ff=96,
                    **_FLEET_TINY).validate(),
    ]


def _uploads_bitwise_equal(ua, ub) -> bool:
    for a, b in zip(ua, ub):
        if a["losses"] != b["losses"]:
            return False
        for xa, xb in zip(jax.tree.leaves(a["params"]),
                          jax.tree.leaves(b["params"])):
            if not bool(jnp.all(xa == xb)):
                return False
    return True


def run_fleet(args) -> int:
    from repro.federated import (STRAGGLER_PROFILES, AsyncFleetConfig,
                                 SimulationConfig, build_fleet, train_fleet,
                                 train_fleet_async)

    sim = SimulationConfig(n_devices=args.fleet, n_domains=4, vocab=256,
                           seq_len=args.seq, device_steps=args.steps,
                           device_batch=args.batch, seed=0)
    corpus = FederatedCorpus.build(seed=sim.seed, n_devices=sim.n_devices,
                                   n_domains=sim.n_domains, vocab=sim.vocab,
                                   alpha=sim.alpha_noniid)
    traffic = STRAGGLER_PROFILES[args.straggler_profile]
    if args.dropout is not None:
        traffic = dataclasses.replace(traffic, dropout_p=args.dropout)
    fleet = build_fleet(sim, corpus, _fleet_families(), traffic=traffic)

    if args.async_rounds <= 0:
        t0 = time.time()
        uploads = train_fleet(fleet, corpus, steps=args.steps,
                              batch=args.batch, seq_len=args.seq,
                              n_hosts=args.n_hosts)
        print(f"sync fleet: {len(uploads)} uploads in {time.time()-t0:.1f}s, "
              f"final losses {[round(u['losses'][-1], 3) for u in uploads[:4]]}…")
        return 0

    acfg = AsyncFleetConfig(
        rounds=args.async_rounds, steps_per_round=args.steps_per_round,
        participation=args.participation, deadline_s=args.deadline_s,
        deadline_policy=args.deadline_policy,
        hierarchical=args.hierarchical)
    t0 = time.time()
    uploads, rep = train_fleet_async(
        fleet, corpus, acfg, batch=args.batch, seq_len=args.seq,
        n_hosts=args.n_hosts, log=print)
    dt = time.time() - t0
    print(f"async fleet ({rep['mode']}): {acfg.rounds} rounds in {dt:.1f}s "
          f"({acfg.rounds / dt:.2f} rounds/s), participation "
          f"{rep['participation_rate']:.2f}, staleness p95 "
          f"{rep['staleness_p95']:.1f}, global comm "
          f"{rep['comm_bytes_global']} B (edge {rep['comm_bytes_edge']} B), "
          f"lost {rep['lost_reports']}")

    if args.check_sync:
        # only meaningful on an ideal fleet: every device online + on
        # time, full participation — then async rounds must reproduce the
        # one-shot synchronous run bit-for-bit
        total = acfg.rounds * acfg.steps_per_round
        ideal = build_fleet(sim, corpus, _fleet_families())
        sync = train_fleet(ideal, corpus, steps=total, batch=args.batch,
                           seq_len=args.seq, n_hosts=args.n_hosts)
        ideal_cfg = dataclasses.replace(acfg, participation=1.0,
                                        deadline_s=float("inf"))
        asy, _ = train_fleet_async(ideal, corpus, ideal_cfg,
                                   batch=args.batch, seq_len=args.seq,
                                   n_hosts=args.n_hosts)
        if not _uploads_bitwise_equal(asy, sync):
            print("CHECK-SYNC FAILED: async rounds != synchronous train_fleet")
            return 1
        print(f"check-sync OK: {acfg.rounds}x{acfg.steps_per_round} async "
              f"rounds == {total}-step train_fleet bit-for-bit")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--variant", default="reduced",
                    choices=["full", "reduced"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--save", default="")
    # fleet mode (see module docstring)
    ap.add_argument("--fleet", type=int, default=0,
                    help="train an N-device federated fleet instead of one model")
    ap.add_argument("--n-hosts", type=int, default=1)
    ap.add_argument("--async-rounds", type=int, default=0,
                    help="> 0 switches the fleet to async participation rounds")
    ap.add_argument("--steps-per-round", type=int, default=4)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--dropout", type=float, default=None,
                    help="per-round dropout probability (overrides profile)")
    ap.add_argument("--deadline-s", type=float, default=float("inf"))
    ap.add_argument("--deadline-policy", default="stale",
                    choices=["drop", "stale", "standby"])
    ap.add_argument("--straggler-profile", default="none",
                    choices=["none", "mild", "harsh"])
    ap.add_argument("--hierarchical", action="store_true")
    ap.add_argument("--check-sync", action="store_true",
                    help="assert async rounds on an ideal fleet reproduce "
                         "synchronous train_fleet bit-for-bit")
    args = ap.parse_args()

    if args.fleet > 0:
        raise SystemExit(run_fleet(args))
    if not args.arch:
        ap.error("--arch is required (unless running --fleet mode)")

    cfg = get_config(args.arch, variant=args.variant)
    if args.variant == "reduced":
        cfg = cfg.replace(vocab_size=args.vocab)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    corpus = FederatedCorpus.build(seed=0, n_devices=4, n_domains=4,
                                   vocab=cfg.vocab_size)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    pshard = named(mesh, param_specs(params, mesh))
    oshard = {"m": named(mesh, param_specs(params, mesh)),
              "v": named(mesh, param_specs(params, mesh)),
              "step": named(mesh, opt_state_specs(params, mesh)["step"])}
    params = jax.device_put(params, pshard)
    sched = cosine_schedule(args.lr, args.steps, warmup=max(args.steps // 20, 1))

    def step_fn(params, opt, batch, lr):
        (loss, metrics), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch, mesh=mesh), has_aux=True)(params)
        params, opt, stats = adamw_update(g, opt, params, lr=lr,
                                          weight_decay=0.01)
        return params, opt, loss, metrics["accuracy"], stats["grad_norm"]

    with mesh:
        jitted = jax.jit(step_fn)
        t0 = time.time()
        for s in range(args.steps):
            batch = make_batch(cfg, corpus, s, args.batch, args.seq)
            params, opt, loss, acc, gn = jitted(params, opt, batch, sched(s))
            if s % max(args.steps // 10, 1) == 0 or s == args.steps - 1:
                print(f"step {s:4d} loss {float(loss):.4f} "
                      f"acc {float(acc):.3f} gnorm {float(gn):.2e} "
                      f"({time.time()-t0:.1f}s)")
    if args.save:
        save_pytree(params, args.save)
        print("saved", args.save)


if __name__ == "__main__":
    main()
