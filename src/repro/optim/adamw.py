"""AdamW in pure JAX, with parameter-freezing masks.

The freeze mask is central to the paper's Phase III (global MoE tuning):
the FFN experts — the overwhelming majority of parameters — stay frozen
while gate / embedding / attention / output layers train (DeepFusion
§IV.D).  Frozen leaves carry **scalar** zero moments, so the optimizer
state for a frozen 671B-expert bank is a few bytes, mirroring the paper's
"reduced memory footprint" claim.

``state_dtype`` lets big configs keep moments in bf16 (HBM-bound 671B
training; see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp


def _is_frozen(mask_leaf) -> bool:
    return mask_leaf is False


def adamw_init(params, *, freeze_mask=None, state_dtype=None):
    """freeze_mask: pytree of bools matching params (True = trainable)."""
    if freeze_mask is None:
        freeze_mask = jax.tree.map(lambda _: True, params)

    def mom(p, trainable):
        dt = state_dtype or jnp.float32
        if not trainable:
            return jnp.zeros((), dt)
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(mom, params, freeze_mask),
        "v": jax.tree.map(mom, params, freeze_mask),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm_clip(grads, max_norm: float):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    gnorm = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(grads, state, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0, freeze_mask=None,
                 clip_norm: float = 1.0):
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    if freeze_mask is None:
        freeze_mask = jax.tree.map(lambda _: True, params)
    step = state["step"] + 1
    if clip_norm:
        grads, gnorm = global_norm_clip(grads, clip_norm)
    else:
        gnorm = jnp.zeros((), jnp.float32)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, trainable):
        if not trainable:
            return p, m, v
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], freeze_mask)
    # unzip the 3-tuples
    treedef = jax.tree.structure(params)
    flat = treedef.flatten_up_to(out)
    new_params = treedef.unflatten([t[0] for t in flat])
    new_m = treedef.unflatten([t[1] for t in flat])
    new_v = treedef.unflatten([t[2] for t in flat])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
