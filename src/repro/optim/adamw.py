"""AdamW in pure JAX, with parameter-freezing masks and moment policies.

The freeze mask is central to the paper's Phase III (global MoE tuning):
the FFN experts — the overwhelming majority of parameters — stay frozen
while gate / embedding / attention / output layers train (DeepFusion
§IV.D).  Frozen leaves carry **scalar** zero moments, so the optimizer
state for a frozen 671B-expert bank is a few bytes, mirroring the paper's
"reduced memory footprint" claim.

Moment storage is governed by a ``quant.MomentPolicy`` (the optimizer
analogue of the cache ``CachePolicy``): the first moment in fp32 or
bf16, the second in fp32 / bf16 / int8 with one per-tensor float32
scale.  Like the cache, **structure carries policy**: an int8-v state
carries a ``"v_scale"`` tree and ``adamw_update`` detects it
structurally, so compiled training loops (``scan_epoch``, the vmapped
fleet driver) need no policy plumbing — they retrace per state
structure.  The master-weight update math is unchanged: moments are
dequantized to fp32, updated, and re-quantized per step, which is what
lets the fleet driver host measurably more devices per host at equal
bytes.

``state_dtype`` remains as the legacy spelling of a uniform moment
dtype (bf16 both moments); ``policy`` supersedes it.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.models import quant


def _is_frozen(mask_leaf) -> bool:
    return mask_leaf is False


def resolve_moment_policy(policy) -> quant.MomentPolicy:
    """Accepts a ``MomentPolicy``, a shorthand string, or None.

    Shorthands: ``""`` (fp32 everything), ``"bf16"`` (both moments
    bf16), ``"int8"`` (m bf16, v int8 + per-tensor scale — the smallest
    state that still tracks fp32 training, see tests/test_quantized.py).
    """
    if policy is None:
        return quant.MomentPolicy()
    if isinstance(policy, quant.MomentPolicy):
        return policy
    if policy == "":
        return quant.MomentPolicy()
    if policy == "bf16":
        return quant.MomentPolicy("bf16", "bf16")
    if policy == "int8":
        return quant.MomentPolicy("bf16", "int8")
    raise ValueError(f"unknown moment policy {policy!r} "
                     "(expected '', 'bf16', 'int8', or a MomentPolicy)")


def adamw_init(params, *, freeze_mask=None, state_dtype=None, policy=None):
    """freeze_mask: pytree of bools matching params (True = trainable).

    ``policy`` (a ``quant.MomentPolicy`` or shorthand string) sets the
    moment storage dtypes; int8 second moments add a ``"v_scale"`` tree
    of scalar float32 scales to the returned state."""
    if freeze_mask is None:
        freeze_mask = jax.tree.map(lambda _: True, params)
    pol = resolve_moment_policy(policy)
    if state_dtype is not None and policy is None:
        m_dt = v_dt = state_dtype
    else:
        m_dt, v_dt = pol.m_storage(), pol.v_storage()

    def mom(dt):
        def init(p, trainable):
            if not trainable:
                return jnp.zeros((), dt)
            return jnp.zeros(p.shape, dt)
        return init

    state = {
        "m": jax.tree.map(mom(m_dt), params, freeze_mask),
        "v": jax.tree.map(mom(v_dt), params, freeze_mask),
        "step": jnp.zeros((), jnp.int32),
    }
    if pol.v_quantized:
        state["v_scale"] = jax.tree.map(
            lambda _: jnp.zeros((), jnp.float32), params)
    return state


def global_norm_clip(grads, max_norm: float):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    gnorm = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(grads, state, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0, freeze_mask=None,
                 clip_norm: float = 1.0):
    """One AdamW step.  Returns (new_params, new_state, stats).

    A state carrying ``"v_scale"`` (int8 second moments) is dequantized
    to fp32 before the update and re-quantized after — the update math
    itself always runs in fp32 master precision."""
    if freeze_mask is None:
        freeze_mask = jax.tree.map(lambda _: True, params)
    v_quantized = "v_scale" in state
    step = state["step"] + 1
    if clip_norm:
        grads, gnorm = global_norm_clip(grads, clip_norm)
    else:
        gnorm = jnp.zeros((), jnp.float32)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, vs, trainable):
        if not trainable:
            return p, m, v, vs
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = quant.dequantize_v(v, vs) if v_quantized \
            else v.astype(jnp.float32)
        v_new = b2 * vf + (1 - b2) * jnp.square(gf)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if v_quantized:
            v_q, vs_new = quant.quantize_v(v_new)
            return p_new, m_new.astype(m.dtype), v_q, vs_new
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype), vs

    vscale = state.get("v_scale")
    if vscale is None:
        vscale = jax.tree.map(lambda _: jnp.zeros((), jnp.float32), params)
    out = jax.tree.map(upd, params, grads, state["m"], state["v"], vscale,
                       freeze_mask)
    # unzip the 4-tuples
    treedef = jax.tree.structure(params)
    flat = treedef.flatten_up_to(out)
    new_params = treedef.unflatten([t[0] for t in flat])
    new_state = {
        "m": treedef.unflatten([t[1] for t in flat]),
        "v": treedef.unflatten([t[2] for t in flat]),
        "step": step,
    }
    if v_quantized:
        new_state["v_scale"] = treedef.unflatten([t[3] for t in flat])
    return new_params, new_state, {"grad_norm": gnorm}
