"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_schedule(lr: float, total_steps: int, warmup: int = 0):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = jnp.minimum(s / max(warmup, 1), 1.0) if warmup else 1.0
        decay = jnp.maximum(1.0 - s / max(total_steps, 1), 0.0)
        return jnp.asarray(lr, jnp.float32) * warm * decay
    return f


def cosine_schedule(lr: float, total_steps: int, warmup: int = 0,
                    min_ratio: float = 0.1):
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.where(s < warmup, s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr, jnp.float32) * warm * cos
    return f
