"""Shared scan-epoch builder for the compiled training loops.

One definition of the multi-step contract (docs/loops.md): scan over
``(stacked batches, step counter)``, lr schedule evaluated inside the
scan, per-step losses returned as a ``(steps,)`` array.  The device,
distillation and tuning epochs all build on this, so the counter/carry
semantics cannot drift between them.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def scan_epoch(step: Callable, schedule: Callable, steps: int) -> Callable:
    """``step: (carry, batch, lr) -> (carry, loss)`` -> scanned
    ``epoch: (carry, batches, start=0) -> (carry, losses)`` over stacked
    batches with the schedule applied to the step counter.

    ``start`` offsets the counter, so an epoch can be one *round* of a
    longer schedule (the async fleet driver passes each device's local
    step, a traced per-lane scalar under ``vmap``) — ``start=0`` is the
    standalone-epoch case and reproduces the historical behaviour
    bit-for-bit."""

    def epoch(carry, batches, start=0):
        def body(carry, inp):
            b, s = inp
            return step(carry, b, schedule(s))

        return jax.lax.scan(body, carry,
                            (batches, start + jnp.arange(steps)))

    return epoch
