"""Shared scan-epoch builder for the compiled training loops.

One definition of the multi-step contract (docs/loops.md): scan over
``(stacked batches, step counter)``, lr schedule evaluated inside the
scan, per-step losses returned as a ``(steps,)`` array.  The device,
distillation and tuning epochs all build on this, so the counter/carry
semantics cannot drift between them.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def scan_epoch(step: Callable, schedule: Callable, steps: int) -> Callable:
    """``step: (carry, batch, lr) -> (carry, loss)`` -> scanned
    ``epoch: (carry, batches) -> (carry, losses)`` over stacked batches
    with the schedule applied to the step counter."""

    def epoch(carry, batches):
        def body(carry, inp):
            b, s = inp
            return step(carry, b, schedule(s))

        return jax.lax.scan(body, carry, (batches, jnp.arange(steps)))

    return epoch
