from repro.optim.adamw import adamw_init, adamw_update, global_norm_clip, \
    resolve_moment_policy
from repro.optim.schedule import cosine_schedule, linear_schedule, constant_schedule
from repro.optim.loops import scan_epoch

__all__ = ["adamw_init", "adamw_update", "global_norm_clip",
           "resolve_moment_policy",
           "cosine_schedule", "linear_schedule", "constant_schedule",
           "scan_epoch"]
