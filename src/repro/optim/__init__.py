from repro.optim.adamw import adamw_init, adamw_update, global_norm_clip
from repro.optim.schedule import cosine_schedule, linear_schedule, constant_schedule
from repro.optim.loops import scan_epoch

__all__ = ["adamw_init", "adamw_update", "global_norm_clip",
           "cosine_schedule", "linear_schedule", "constant_schedule",
           "scan_epoch"]
