from repro.checkpoint.io import save_pytree, load_pytree

__all__ = ["save_pytree", "load_pytree"]
