"""Checkpointing: path-flattened npz pytree save/restore (no orbax dep)."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import path_str

_SEP = "|"


def save_pytree(tree, path: str) -> None:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays: Dict[str, np.ndarray] = {}
    for p, leaf in flat:
        key = path_str(p).replace("/", _SEP)
        x = np.asarray(jax.device_get(leaf))
        if x.dtype == jnp.bfloat16:
            arrays[key + "#bf16"] = x.astype(np.float32)
        else:
            arrays[key] = x
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def load_pytree(template, path: str):
    """Restore into the structure (and dtypes) of ``template``."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = path_str(p).replace("/", _SEP)
        if key in data:
            arr = data[key]
        elif key + "#bf16" in data:
            arr = data[key + "#bf16"]
        else:
            raise KeyError(f"checkpoint missing {key}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)
