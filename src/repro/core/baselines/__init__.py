from repro.core.baselines.centralized import run_centralized
from repro.core.baselines.fedavg import run_fedavg
from repro.core.baselines.fedjets import run_fedjets
from repro.core.baselines.fedkmt import run_fedkmt
from repro.core.baselines.ofa_kd import run_ofa_kd

__all__ = ["run_centralized", "run_fedavg", "run_fedjets", "run_fedkmt",
           "run_ofa_kd"]
