"""FedAvg [McMahan et al., AISTATS'17] — classic multi-round FL.

Every device trains *the same* small dense model (architecture-homogeneous
by construction); the server element-wise averages each round.  Included
as the canonical FL reference: its per-round down+up traffic of the full
model is what DeepFusion's one-shot design avoids (Fig. 8).
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.data.federated import FederatedCorpus
from repro.federated.simulation import SimulationConfig, evaluate_model
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update
from repro.utils.pytree import tree_average, tree_bytes


def run_fedavg(sim: SimulationConfig, model_cfg: ModelConfig, *,
               rounds: int = 5, local_steps: int = 8, batch: int = 8,
               lr: float = 3e-3, corpus: FederatedCorpus = None,
               log: Callable[[str], None] = print):
    corpus = corpus or FederatedCorpus.build(
        seed=sim.seed, n_devices=sim.n_devices, n_domains=sim.n_domains,
        vocab=sim.vocab, alpha=sim.alpha_noniid)
    global_params = M.init_params(jax.random.PRNGKey(sim.seed + 11), model_cfg)

    @jax.jit
    def local_step(params, opt, b, lr_now):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, model_cfg, b), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=lr_now)
        return params, opt, loss

    model_bytes = tree_bytes(global_params)
    comm = 0
    for r in range(rounds):
        locals_ = []
        for n in range(sim.n_devices):
            params = global_params
            opt = adamw_init(params)
            for s in range(local_steps):
                b = corpus.device_batch(n, batch, sim.seq_len,
                                        step=r * local_steps + s)
                params, opt, loss = local_step(params, opt, b, lr)
            locals_.append(params)
            comm += 2 * model_bytes  # download + upload
        global_params = tree_average(locals_)
        log(f"fedavg round {r}: loss {float(loss):.3f}")
    metrics = evaluate_model(global_params, model_cfg, corpus,
                             seq_len=sim.seq_len)
    return global_params, {"metrics": metrics, "comm_bytes": int(comm),
                           "corpus": corpus}
