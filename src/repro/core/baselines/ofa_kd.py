"""OFA-KD [Hao et al., NeurIPS'23] — cross-architecture KD via logit space.

Instead of aligning features in a learned common space (VAA), OFA-KD
projects the student's *intermediate* stage features into the logits
space with small exit heads and aligns each against the **teacher's
final logits** (KL).  We keep everything else identical to the
DeepFusion pipeline (clustering, proxies, merge, tune) so the
feature-alignment mechanism is the only variable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.core import distill as D
from repro.core import merge
from repro.data.federated import FederatedCorpus
from repro.federated.server import DeepFusionServer, ServerConfig
from repro.federated.simulation import SimulationConfig, evaluate_model
from repro.models import layers
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule


def init_ofa_heads(key, *, n_stages: int, d_student: int, vocab: int,
                   rank: int = 64):
    """Low-rank exit heads: stage feature -> logits."""
    ks = jax.random.split(key, 2)
    return {
        "down": layers.dense_init(ks[0], (n_stages, d_student, rank), 1),
        "up": layers.dense_init(ks[1], (n_stages, rank, vocab), 1),
    }


def ofa_loss(trainable, s_cfg: ModelConfig, t_params, t_cfg: ModelConfig,
             batch, teacher_out, *, beta: float, temperature: float,
             n_stages: int, gamma_stage: float = 0.5, mesh=None):
    s_params, heads = trainable["student"], trainable["ofa"]
    h_s, aux, _, stages = M.backbone(s_params, s_cfg, batch, mesh=mesh,
                                     collect_stages=True)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    ce, kl, tok, cor = D.chunked_ce_kl(
        s_params, s_cfg, h_s, t_params, t_cfg, teacher_out["h"], labels, mask,
        temperature=temperature)
    ce = ce / jnp.maximum(tok, 1.0)
    kl = kl / jnp.maximum(tok, 1.0)
    # stage exits vs teacher final logits
    t_logits = M._head(t_params, t_cfg, teacher_out["h"])
    logp_t = jax.lax.stop_gradient(
        jax.nn.log_softmax(t_logits / temperature, axis=-1))
    p_t = jnp.exp(logp_t)
    s_stages = D.select_stages(stages, n_stages)
    stage_kl = jnp.zeros((), jnp.float32)
    for j, f in enumerate(s_stages):
        z = (f.astype(jnp.float32) @ heads["down"][j]) @ heads["up"][j]
        logp_s = jax.nn.log_softmax(z / temperature, axis=-1)
        stage_kl += jnp.mean(jnp.sum(p_t * (logp_t - logp_s), -1)) * temperature ** 2
    stage_kl = stage_kl / n_stages
    total = ce + beta * kl + gamma_stage * stage_kl + aux
    return total, {"ce": ce, "kl": kl, "stage_kl": stage_kl,
                   "accuracy": cor / jnp.maximum(tok, 1.0)}


class OFAServer(DeepFusionServer):
    def distill_proxy(self, proxy_item, base_cfg, *, init_params=None,
                      seed_offset: int = 0):
        scfg = self.cfg
        t_cfg = self.device_cfgs[proxy_item["arch"]]
        t_params = proxy_item["params"]
        s_params = init_params if init_params is not None else M.init_params(
            jax.random.PRNGKey(scfg.seed + 404 + seed_offset), base_cfg)
        heads = init_ofa_heads(jax.random.PRNGKey(scfg.seed + 505 + seed_offset),
                               n_stages=scfg.n_stages,
                               d_student=base_cfg.d_model,
                               vocab=base_cfg.vocab_size)
        trainable = {"student": s_params, "ofa": heads}
        opt = adamw_init(trainable)
        sched = cosine_schedule(scfg.distill_lr, scfg.distill_steps,
                                warmup=max(scfg.distill_steps // 20, 1))

        def raw_step(trainable, opt, t_params, batch, lr):
            teacher_out = D.teacher_forward(t_params, t_cfg, batch,
                                            n_stages=scfg.n_stages)
            (loss, metrics), grads = jax.value_and_grad(ofa_loss, has_aux=True)(
                trainable, base_cfg, t_params, t_cfg, batch, teacher_out,
                beta=scfg.beta, temperature=scfg.temperature,
                n_stages=scfg.n_stages)
            trainable, opt, _ = adamw_update(grads, opt, trainable, lr=lr)
            return trainable, opt, loss

        step = jax.jit(raw_step)
        hist = []
        for s in range(scfg.distill_steps):
            batch = self.corpus.mixed_eval_batch(scfg.distill_batch,
                                                 scfg.seq_len, seed_salt=s)
            trainable, opt, loss = step(trainable, opt, t_params, batch,
                                        sched(s))
            hist.append(float(loss))
        self.log(f"OFA-KD: proxy c{proxy_item['cluster']} distilled "
                 f"loss {hist[0]:.3f}->{hist[-1]:.3f}")
        return trainable["student"], hist


def run_ofa_kd(sim: SimulationConfig, server_cfg: ServerConfig,
               device_cfgs: Sequence[ModelConfig], *, uploads, corpus,
               log: Callable[[str], None] = print):
    server = OFAServer(server_cfg, corpus, device_cfgs, log=log)
    moe_params, report = server.run(uploads)
    metrics = evaluate_model(moe_params, server_cfg.moe_cfg, corpus,
                             seq_len=sim.seq_len)
    report["metrics"] = metrics
    return moe_params, report
