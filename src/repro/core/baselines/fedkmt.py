"""FedKMT/FedMKT [Fan et al., COLING'25] — logits-only federated KD.

Same one-shot uploads and clustering as DeepFusion, but knowledge is
transferred through **final logits only** (KL), with no feature-level
alignment.  Ablation target: quantifies what the VAA feature path adds
(paper §V.C "Cross-architecture Knowledge Distillation").

Implementation: the DeepFusion server pipeline with α = 0 (no L_FM) —
identical budgets everywhere else, so differences isolate the mechanism.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.data.federated import FederatedCorpus
from repro.federated.server import DeepFusionServer, ServerConfig
from repro.federated.simulation import (SimulationConfig, evaluate_model,
                                        run_deepfusion)
from repro.models.config import ModelConfig


def run_fedkmt(sim: SimulationConfig, server_cfg: ServerConfig,
               device_cfgs: Sequence[ModelConfig], *, uploads=None,
               corpus: FederatedCorpus = None,
               log: Callable[[str], None] = print):
    cfg = dataclasses.replace(server_cfg, alpha=0.0)
    return run_deepfusion(sim, cfg, device_cfgs, uploads=uploads,
                          corpus=corpus, log=log)
