"""FedJETS [Dun et al., 2023] — federated MoE with per-device pruned MoEs.

Each device hosts a *compact MoE network pruned from the global MoE*: the
full attention/embedding backbone plus a small subset of the experts
(here ``experts_per_device``).  Multi-round: every round each device
downloads its pruned model, trains locally, uploads; the server averages
the backbone across all devices and each expert across its owners.

This is the baseline whose device-memory and communication profile the
paper attacks (Figs. 7, 8): the pruned model still carries the MoE
backbone and is several times larger than a lightweight on-device LLM.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedCorpus
from repro.federated.simulation import SimulationConfig, evaluate_model
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update
from repro.utils.pytree import tree_average, tree_bytes


def _slice_experts(moe_params, expert_ids):
    """Prune the global MoE down to the given expert slots."""
    idx = jnp.asarray(expert_ids)

    def prune(path_leaf):
        return path_leaf

    pruned = jax.tree.map(lambda x: x, moe_params)  # shallow copy
    for sub in pruned["blocks"]:
        mo = pruned["blocks"][sub].get("moe")
        if mo is None:
            continue
        mo = dict(mo)
        mo["router"] = mo["router"][:, :, idx] if mo["router"].ndim == 3 \
            else mo["router"][:, idx]
        for w in ("wi_gate", "wi_up", "wo"):
            mo[w] = mo[w][:, idx]
        pruned["blocks"][sub] = dict(pruned["blocks"][sub], moe=mo)
    return pruned


def _write_back(global_params, local_params_list, assignments, E):
    """Average backbone across devices; write experts back to owners."""
    # backbone average: everything except the expert tensors + router cols
    def strip(p):
        q = jax.tree.map(lambda x: x, p)
        for sub in q["blocks"]:
            if "moe" in q["blocks"][sub]:
                b = dict(q["blocks"][sub])
                del b["moe"]
                q["blocks"][sub] = b
        return q

    avg_backbone = tree_average([strip(p) for p in local_params_list])
    out = jax.tree.map(lambda x: x, global_params)
    for k in avg_backbone:
        if k != "blocks":
            out[k] = avg_backbone[k]
    for sub in out["blocks"]:
        blk = dict(out["blocks"][sub])
        for name in blk:
            if name != "moe":
                blk[name] = avg_backbone["blocks"][sub][name]
        # experts: average over owning devices
        if "moe" in blk:
            mo = dict(blk["moe"])
            for w in ("wi_gate", "wi_up", "wo"):
                acc = np.asarray(mo[w]).copy()
                cnt = np.zeros(E)
                buf = np.zeros_like(acc)
                for lp, ids in zip(local_params_list, assignments):
                    lw = np.asarray(lp["blocks"][sub]["moe"][w])
                    for j, e in enumerate(ids):
                        buf[:, e] += lw[:, j]
                        cnt[e] += 1
                for e in range(E):
                    if cnt[e]:
                        acc[:, e] = buf[:, e] / cnt[e]
                mo[w] = jnp.asarray(acc)
            # router columns: average over owners
            r = np.asarray(mo["router"]).copy()
            rbuf = np.zeros_like(r)
            rcnt = np.zeros(E)
            for lp, ids in zip(local_params_list, assignments):
                lr_ = np.asarray(lp["blocks"][sub]["moe"]["router"])
                for j, e in enumerate(ids):
                    rbuf[..., e] += lr_[..., j]
                    rcnt[e] += 1
            for e in range(E):
                if rcnt[e]:
                    r[..., e] = rbuf[..., e] / rcnt[e]
            mo["router"] = jnp.asarray(r)
            blk["moe"] = mo
        out["blocks"][sub] = blk
    return out


def run_fedjets(sim: SimulationConfig, moe_cfg: ModelConfig, *,
                rounds: int = 3, local_steps: int = 8, batch: int = 8,
                lr: float = 2e-3, experts_per_device: int = 2,
                corpus: FederatedCorpus = None,
                log: Callable[[str], None] = print):
    corpus = corpus or FederatedCorpus.build(
        seed=sim.seed, n_devices=sim.n_devices, n_domains=sim.n_domains,
        vocab=sim.vocab, alpha=sim.alpha_noniid)
    E = moe_cfg.n_experts
    ec = experts_per_device
    local_cfg = moe_cfg.replace(n_experts=ec, top_k=min(moe_cfg.top_k, ec))
    global_params = M.init_params(jax.random.PRNGKey(sim.seed + 13), moe_cfg)
    rng = np.random.default_rng(sim.seed + 17)

    @jax.jit
    def local_step(params, opt, b, lr_now):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, local_cfg, b), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=lr_now)
        return params, opt, loss

    comm = 0
    local_bytes = None
    for r in range(rounds):
        locals_, assignments = [], []
        for n in range(sim.n_devices):
            ids = sorted(rng.choice(E, size=ec, replace=False).tolist())
            lp = _slice_experts(global_params, ids)
            if local_bytes is None:
                local_bytes = tree_bytes(lp)
            opt = adamw_init(lp)
            for s in range(local_steps):
                b = corpus.device_batch(n, batch, sim.seq_len,
                                        step=r * local_steps + s)
                lp, opt, loss = local_step(lp, opt, b, lr)
            locals_.append(lp)
            assignments.append(ids)
            comm += 2 * local_bytes
        global_params = _write_back(global_params, locals_, assignments, E)
        log(f"fedjets round {r}: loss {float(loss):.3f}")
    metrics = evaluate_model(global_params, moe_cfg, corpus,
                             seq_len=sim.seq_len)
    return global_params, {"metrics": metrics, "comm_bytes": int(comm),
                           "local_model_bytes": int(local_bytes or 0),
                           "corpus": corpus}
