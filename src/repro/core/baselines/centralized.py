"""Centralized MoE training — the paper's upper bound ("DeepSpeed" role).

All private device data is pooled at the server (violating the FL
constraint — that is the point of the upper bound) and the global MoE is
trained end-to-end with full-parameter updates.  Communication cost is
the raw data upload.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import numpy as np

from repro.data.federated import FederatedCorpus
from repro.federated.simulation import SimulationConfig, evaluate_model
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule


def run_centralized(sim: SimulationConfig, moe_cfg: ModelConfig, *,
                    steps: int = 120, batch: int = 8, lr: float = 1e-3,
                    corpus: FederatedCorpus = None,
                    log: Callable[[str], None] = print):
    corpus = corpus or FederatedCorpus.build(
        seed=sim.seed, n_devices=sim.n_devices, n_domains=sim.n_domains,
        vocab=sim.vocab, alpha=sim.alpha_noniid)
    params = M.init_params(jax.random.PRNGKey(sim.seed + 7), moe_cfg)
    opt = adamw_init(params)
    sched = cosine_schedule(lr, steps, warmup=max(steps // 20, 1))

    @jax.jit
    def step_fn(params, opt, b, lr_now):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, moe_cfg, b), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=lr_now)
        return params, opt, loss

    hist = []
    for s in range(steps):
        # pooled data: sample across devices' domains uniformly
        b = corpus.mixed_eval_batch(batch, sim.seq_len, seed_salt=77_000 + s)
        params, opt, loss = step_fn(params, opt, b, sched(s))
        hist.append(float(loss))
    log(f"centralized: loss {hist[0]:.3f}->{hist[-1]:.3f}")
    metrics = evaluate_model(params, moe_cfg, corpus, seq_len=sim.seq_len)
    # comm: every device ships its raw data (tokens, int32)
    tokens_per_device = sim.device_steps * sim.device_batch * (sim.seq_len + 1)
    comm = int(sim.n_devices * tokens_per_device * 4)
    return params, {"metrics": metrics, "comm_bytes": comm, "history": hist,
                    "corpus": corpus}
