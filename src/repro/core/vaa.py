"""View-Aligned Attention (VAA) — the paper's core module (§IV.C, Fig. 5).

The student (MoE base model) and teacher (proxy of on-device LLMs) have
different architectures and *predictive perspectives*.  VAA lets the
student blend its own multi-stage features through self-attention into a
perspective comparable with the teacher's, after which plain feature
matching (MSE) works.

Three steps (paper numbering):
 1. patchify each student stage j into P_q/J patches and project to a
    common dim d via C_j.  TPU adaptation: the paper's "convolutional
    layers" come from vision KD; on token sequences a non-overlapping
    strided conv == mean-pool over S/P buckets followed by a dense
    projection — a reshaped matmul, MXU-friendly, no halo exchange
    (see DESIGN.md §5).
 2. multi-head self-attention over the concatenated (B, P_q, d) features
    (Eq. 8).
 3. split back into J stages and project each to the teacher's stage
    width; feature-matching loss against the (pooled) teacher stages
    (Eq. 9).
"""
from __future__ import annotations

import math
from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.models import layers


def patchify(x, n_patches: int):
    """(B, S, D) -> (B, n_patches, D) by mean-pooling S into buckets.

    Always returns exactly ``n_patches`` patches: short sequences
    (S < n_patches) are edge-padded up to n_patches first, so downstream
    per-stage slices of the concatenated (B, P_q, d) query block stay
    aligned (vaa_apply step 3) and L_FM shapes always match.
    """
    B, S, D = x.shape
    P = n_patches
    pad = (-S) % P
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)), mode="edge")
    return x.reshape(B, P, -1, D).mean(axis=2)


def init_vaa(key, *, n_stages: int, d_student: int, d_teacher: int,
             d: int = 256, n_heads: int = 4, p_q: int = 64,
             dtype=jnp.float32):
    """Parameters of the VAA module.  p_q = total queries over all stages."""
    assert p_q % n_stages == 0, "P_q must divide into J stages"
    ks = jax.random.split(key, 6)
    return {
        "stage_proj": layers.dense_init(ks[0], (n_stages, d_student, d), 1, dtype),
        "wq": layers.dense_init(ks[1], (d, d), 0, dtype),
        "wk": layers.dense_init(ks[2], (d, d), 0, dtype),
        "wv": layers.dense_init(ks[3], (d, d), 0, dtype),
        "wo": layers.dense_init(ks[4], (d, d), 0, dtype),
        "out_proj": layers.dense_init(ks[5], (n_stages, d, d_teacher), 1, dtype),
    }


def vaa_apply(p, student_stages: Sequence[jax.Array], *, n_heads: int,
              p_q: int) -> List[jax.Array]:
    """student_stages: J tensors (B, S, d_S) -> J tensors (B, P_q/J, d_T)."""
    J = len(student_stages)
    P = p_q // J
    d = p["wq"].shape[0]

    # step 1: patchify + project each stage (Eq. 7)
    feats = []
    for j, f in enumerate(student_stages):
        patches = patchify(f.astype(jnp.float32), P)       # (B, P, d_S)
        feats.append(patches @ p["stage_proj"][j].astype(jnp.float32))
    fs = jnp.concatenate(feats, axis=1)                     # (B, P_q, d)

    # step 2: multi-head self-attention (Eq. 8)
    B = fs.shape[0]
    hd = d // n_heads
    q = (fs @ p["wq"]).reshape(B, -1, n_heads, hd)
    k = (fs @ p["wk"]).reshape(B, -1, n_heads, hd)
    v = (fs @ p["wv"]).reshape(B, -1, n_heads, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(B, -1, d)
    fs2 = o @ p["wo"]

    # step 3: split stages + project to teacher widths
    out = []
    for j in range(J):
        blk = fs2[:, j * P:(j + 1) * P]
        out.append(blk @ p["out_proj"][j].astype(jnp.float32))
    return out


def feature_matching_loss(p, student_stages, teacher_stages, *, n_heads: int,
                          p_q: int):
    """L_FM (Eq. 9): MSE between VAA-blended student and pooled teacher."""
    J = len(student_stages)
    P = p_q // J
    blended = vaa_apply(p, student_stages, n_heads=n_heads, p_q=p_q)
    loss = jnp.zeros((), jnp.float32)
    for j in range(J):
        t = patchify(teacher_stages[j].astype(jnp.float32), P)
        t = t / (jnp.linalg.norm(t, axis=-1, keepdims=True) + 1e-6)
        s = blended[j]
        s = s / (jnp.linalg.norm(s, axis=-1, keepdims=True) + 1e-6)
        loss = loss + jnp.mean(jnp.square(s - t))
    return loss / J
