"""DeepFusion core: the paper's contribution as composable JAX modules.

Pipeline (paper Fig. 3):
  Phase I   `clustering` + `proxy`   — local knowledge clustering
  Phase II  `vaa` + `distill`        — cross-architecture KD (VAA module)
  Phase III `merge` + `tuning`       — global MoE merge + frozen-expert tune
Baselines in `baselines/` (FedAvg, FedJETS, FedKMT, OFA-KD, centralized).
"""
from repro.core import clustering, distill, merge, proxy, tuning, vaa

__all__ = ["clustering", "distill", "merge", "proxy", "tuning", "vaa"]
