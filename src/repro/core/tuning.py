"""Phase III — global MoE model tuning (paper §IV.D).

FFN experts (routed *and* shared — the overwhelming majority of params)
are **frozen**; the embedding, self-attention, gate (router) and output
layers are fine-tuned on server-side public data.  The freeze mask feeds
``repro.optim.adamw``, whose frozen leaves carry scalar moments — the
"reduced memory footprint and faster convergence" claim of the paper.
"""
from __future__ import annotations

import re
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, scan_epoch
from repro.utils.pytree import flatten_with_paths, path_str

_FROZEN = re.compile(r"moe/(wi_gate|wi_up|wo)$|moe/shared/")


def expert_freeze_mask(params) -> Dict:
    """True = trainable.  Freezes routed + shared expert FFN weights."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    mask = [not _FROZEN.search(path_str(p)) for p, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, mask)


def trainable_fraction(params) -> float:
    mask = expert_freeze_mask(params)
    tot = sum(x.size for x in jax.tree.leaves(params))
    train = sum(x.size for x, m in
                zip(jax.tree.leaves(params), jax.tree.leaves(mask)) if m)
    return train / max(tot, 1)


def make_tune_step(cfg: ModelConfig, freeze_mask, *, weight_decay=0.01,
                   mesh=None):
    def step(params, opt_state, batch, lr):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch, mesh=mesh), has_aux=True)(params)
        params, opt_state, stats = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay,
            freeze_mask=freeze_mask)
        metrics.update(stats)
        return params, opt_state, loss, metrics

    return step


def make_tune_epoch(cfg: ModelConfig, freeze_mask, *, steps, schedule,
                    weight_decay=0.01, mesh=None):
    """Scan-compiled multi-step tuning (see docs/loops.md): jit-able
    ``(params, opt_state, batches) -> (params, opt_state, losses)`` over
    stacked ``(steps, B, S)`` batches, lr schedule evaluated inside the
    scan — one host sync per Phase III epoch."""
    step_fn = make_tune_step(cfg, freeze_mask, weight_decay=weight_decay,
                             mesh=mesh)

    def carry_step(carry, b, lr):
        params, opt_state, loss, _ = step_fn(*carry, b, lr)
        return (params, opt_state), loss

    scanned = scan_epoch(carry_step, schedule, steps)

    def epoch(params, opt_state, batches):
        (params, opt_state), losses = scanned((params, opt_state), batches)
        return params, opt_state, losses

    return epoch


def init_tuning(params, *, state_dtype=None):
    mask = expert_freeze_mask(params)
    return mask, adamw_init(params, freeze_mask=mask, state_dtype=state_dtype)
