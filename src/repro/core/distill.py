"""Cross-architecture knowledge distillation (paper §IV.C).

``L_KD = L_CE + α·L_FM + β·L_KL`` (Eq. 11):

* L_CE — student's own autoregressive loss on (public) server data;
* L_FM — VAA feature matching across J representation stages (Eq. 9);
* L_KL — KL(teacher ‖ student) over next-token distributions (Eq. 10),
  computed *sequence-chunked* so (B, S, V) teacher+student logits are
  never materialised at once (on TPU the fused ``kd_loss`` Pallas kernel
  does the same in VMEM tiles — the KD-server hot spot for 100k+ vocabs).

The teacher runs once per batch (no gradients); its stage features and
final hidden states are cached and reused by the student update.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.core import vaa as vaa_mod
from repro.optim import scan_epoch


# ---------------------------------------------------------------------------
# stage selection
# ---------------------------------------------------------------------------

def select_stages(stages, n_stages: int) -> List[jax.Array]:
    """(nG, B, S, D) scan outputs -> J evenly spaced stage tensors."""
    nG = stages.shape[0]
    idx = np.unique(np.round(np.linspace(1, nG, n_stages)).astype(int) - 1)
    while len(idx) < n_stages:  # tiny models: repeat last stage
        idx = np.append(idx, idx[-1])
    return [stages[i] for i in idx]


def teacher_forward(t_params, t_cfg: ModelConfig, batch, *, n_stages: int,
                    mesh=None):
    """Frozen teacher pass.  Returns dict with stage features + final h."""
    h, _, _, stages = M.backbone(t_params, t_cfg, batch, mesh=mesh,
                                 collect_stages=True)
    return {
        "h": jax.lax.stop_gradient(h),
        "stages": [jax.lax.stop_gradient(s)
                   for s in select_stages(stages, n_stages)],
    }


# ---------------------------------------------------------------------------
# chunked CE + KL
# ---------------------------------------------------------------------------

def _head_w(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def chunked_ce_kl(s_params, s_cfg: ModelConfig, h_s, t_params, t_cfg,
                  h_t, labels, mask, *, temperature: float = 1.0,
                  use_pallas: bool = False):
    """Scan over sequence chunks; returns (ce_sum, kl_sum, tok, correct)."""
    B, S, _ = h_s.shape
    C = min(s_cfg.loss_chunk, S)
    pad = (-S) % C
    if pad:
        h_s = jnp.pad(h_s, ((0, 0), (0, pad), (0, 0)))
        h_t = jnp.pad(h_t, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = h_s.shape[1] // C
    hs = h_s.reshape(B, n, C, -1).transpose(1, 0, 2, 3)
    ht = h_t.reshape(B, n, C, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, C).transpose(1, 0, 2)
    mc = mask.reshape(B, n, C).transpose(1, 0, 2)
    tau = temperature

    def body(carry, inp):
        ce_s, kl_s, tok_s, cor_s = carry
        hh_s, hh_t, ll, mm = inp
        if use_pallas:
            from repro.kernels.kd_loss import ops as kd_ops
            ce, kl, correct = kd_ops.ce_kl_from_hidden(
                hh_s, _head_w(s_params, s_cfg), hh_t, _head_w(t_params, t_cfg),
                ll, tau=tau,
                softcap_s=s_cfg.final_logit_softcap,
                softcap_t=t_cfg.final_logit_softcap)
        else:
            logit_s = M._head(s_params, s_cfg, hh_s)
            logit_t = jax.lax.stop_gradient(M._head(t_params, t_cfg, hh_t))
            lse_s = jax.nn.logsumexp(logit_s, axis=-1)
            gold = jnp.take_along_axis(logit_s, ll[..., None], -1)[..., 0]
            ce = lse_s - gold
            logp_s = jax.nn.log_softmax(logit_s / tau, axis=-1)
            logp_t = jax.nn.log_softmax(logit_t / tau, axis=-1)
            p_t = jnp.exp(logp_t)
            kl = jnp.sum(p_t * (logp_t - logp_s), axis=-1) * (tau ** 2)
            correct = (jnp.argmax(logit_s, -1) == ll).astype(jnp.float32)
        mmf = mm.astype(jnp.float32)
        return (ce_s + jnp.sum(ce * mmf), kl_s + jnp.sum(kl * mmf),
                tok_s + jnp.sum(mmf), cor_s + jnp.sum(correct * mmf)), 0

    if s_cfg.remat:
        body = jax.checkpoint(body)
    (ce, kl, tok, cor), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32),) * 4, (hs, ht, lc, mc))
    return ce, kl, tok, cor


# ---------------------------------------------------------------------------
# full distillation objective
# ---------------------------------------------------------------------------

def distill_loss(trainable, s_cfg: ModelConfig, t_params, t_cfg: ModelConfig,
                 batch, teacher_out, *, alpha: float = 1.0, beta: float = 1.0,
                 temperature: float = 2.0, n_stages: int = 4,
                 vaa_heads: int = 4, p_q: int = 64, mesh=None):
    """trainable = {"student": student_params, "vaa": vaa_params}.

    Eq. 11: L_KD = L_CE + α L_FM + β L_KL.
    """
    s_params, vaa_params = trainable["student"], trainable["vaa"]
    h_s, aux, _, stages = M.backbone(s_params, s_cfg, batch, mesh=mesh,
                                     collect_stages=True)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    ce, kl, tok, cor = chunked_ce_kl(
        s_params, s_cfg, h_s, t_params, t_cfg, teacher_out["h"], labels, mask,
        temperature=temperature, use_pallas=s_cfg.use_pallas)
    ce = ce / jnp.maximum(tok, 1.0)
    kl = kl / jnp.maximum(tok, 1.0)
    s_stages = select_stages(stages, n_stages)
    fm = vaa_mod.feature_matching_loss(
        vaa_params, s_stages, teacher_out["stages"],
        n_heads=vaa_heads, p_q=p_q)
    total = ce + alpha * fm + beta * kl + aux
    metrics = {"ce": ce, "kl": kl, "fm": fm, "aux": aux,
               "accuracy": cor / jnp.maximum(tok, 1.0)}
    return total, metrics


def make_distill_step(s_cfg: ModelConfig, t_cfg: ModelConfig, *, alpha, beta,
                      temperature, n_stages, vaa_heads, p_q, optimizer_update,
                      mesh=None):
    """Builds a jit-able (trainable, opt_state, t_params, batch, lr) step."""

    def step(trainable, opt_state, t_params, batch, lr):
        teacher_out = teacher_forward(t_params, t_cfg, batch,
                                      n_stages=n_stages, mesh=mesh)
        (loss, metrics), grads = jax.value_and_grad(
            distill_loss, has_aux=True)(
                trainable, s_cfg, t_params, t_cfg, batch, teacher_out,
                alpha=alpha, beta=beta, temperature=temperature,
                n_stages=n_stages, vaa_heads=vaa_heads, p_q=p_q, mesh=mesh)
        trainable, opt_state, stats = optimizer_update(
            grads, opt_state, trainable, lr=lr)
        metrics.update(stats)
        return trainable, opt_state, loss, metrics

    return step


def make_distill_epoch(s_cfg: ModelConfig, t_cfg: ModelConfig, *, steps,
                       schedule, alpha, beta, temperature, n_stages,
                       vaa_heads, p_q, optimizer_update, mesh=None):
    """Scan-compiled multi-step distillation (see docs/loops.md).

    Builds a jit-able ``(trainable, opt_state, t_params, batches) ->
    (trainable, opt_state, losses)`` over pre-generated stacked batches
    ``{tokens/labels: (steps, B, S)}``.  The lr ``schedule`` is evaluated
    inside the scan from the step counter, so one compiled program covers
    the whole Phase II epoch with a single host sync at the end.
    """
    step_fn = make_distill_step(
        s_cfg, t_cfg, alpha=alpha, beta=beta, temperature=temperature,
        n_stages=n_stages, vaa_heads=vaa_heads, p_q=p_q,
        optimizer_update=optimizer_update, mesh=mesh)

    def epoch(trainable, opt_state, t_params, batches):
        def carry_step(carry, b, lr):
            trainable, opt_state, loss, _ = step_fn(*carry, t_params, b, lr)
            return (trainable, opt_state), loss

        (trainable, opt_state), losses = scan_epoch(
            carry_step, schedule, steps)((trainable, opt_state), batches)
        return trainable, opt_state, losses

    return epoch
