"""Local knowledge clustering (paper §IV.B).

Devices upload low-rank data embeddings e_n alongside their trained
on-device LLMs.  The server builds the cosine-similarity matrix Π
(Eq. 6) and groups devices into K local knowledge domains with KMeans.

The paper weight-averages the models inside each cluster (Fig. 4), which
requires identical parameter structure — it implicitly assumes "models of
the same type" end up together.  We make that explicit: clustering is
*architecture-constrained* — after KMeans on embeddings, devices whose
architecture differs from their cluster's majority architecture are
re-assigned to the nearest (by centroid cosine) cluster whose majority
architecture matches theirs; if none exists, they form the seed of a
spill cluster.  This keeps every proxy model well-defined while
preserving the embedding-driven domain structure.

No sklearn dependency: spherical k-means++ in numpy.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


def cosine_similarity_matrix(embeddings: np.ndarray) -> np.ndarray:
    """Π = [π_{n1,n2}] (Eq. 6)."""
    e = embeddings / (np.linalg.norm(embeddings, axis=1, keepdims=True) + 1e-9)
    return e @ e.T


def _kmeans_pp_init(rng, e: np.ndarray, k: int) -> np.ndarray:
    n = len(e)
    centroids = [e[rng.integers(n)]]
    for _ in range(1, k):
        d = np.min(
            [1.0 - e @ c for c in centroids], axis=0)  # cosine distance
        d = np.maximum(d, 0.0)
        probs = d / d.sum() if d.sum() > 0 else np.full(n, 1.0 / n)
        centroids.append(e[rng.choice(n, p=probs)])
    return np.stack(centroids)


def spherical_kmeans(embeddings: np.ndarray, k: int, *, seed: int = 0,
                     iters: int = 50):
    """Returns (labels (N,), centroids (K, D))."""
    rng = np.random.default_rng(seed)
    e = embeddings / (np.linalg.norm(embeddings, axis=1, keepdims=True) + 1e-9)
    k = min(k, len(e))
    c = _kmeans_pp_init(rng, e, k)
    labels = np.zeros(len(e), np.int32)
    for _ in range(iters):
        sims = e @ c.T
        new_labels = np.argmax(sims, axis=1).astype(np.int32)
        if np.array_equal(new_labels, labels) and _ > 0:
            break
        labels = new_labels
        for j in range(k):
            members = e[labels == j]
            if len(members):
                m = members.mean(axis=0)
                c[j] = m / (np.linalg.norm(m) + 1e-9)
            else:  # re-seed empty cluster at the farthest point
                far = np.argmin(np.max(e @ c.T, axis=1))
                c[j] = e[far]
    return labels, c


@dataclasses.dataclass
class ClusterResult:
    labels: np.ndarray            # (N,) cluster id per device
    centroids: np.ndarray         # (K, D)
    similarity: np.ndarray        # (N, N) Π matrix
    members: List[List[int]]      # device ids per cluster


def cluster_devices(embeddings: np.ndarray, k: int, *,
                    arch_ids: Optional[Sequence[int]] = None,
                    seed: int = 0) -> ClusterResult:
    """KMeans over data embeddings, architecture-constrained (see module doc)."""
    sim = cosine_similarity_matrix(embeddings)
    labels, centroids = spherical_kmeans(embeddings, k, seed=seed)
    k = len(centroids)

    if arch_ids is not None:
        arch_ids = np.asarray(arch_ids)
        e = embeddings / (np.linalg.norm(embeddings, axis=1, keepdims=True) + 1e-9)
        # majority arch per cluster
        majority = {}
        for j in range(k):
            m = arch_ids[labels == j]
            majority[j] = np.bincount(m).argmax() if len(m) else -1
        sims = e @ centroids.T
        for n in range(len(labels)):
            if majority[labels[n]] in (-1, arch_ids[n]):
                continue
            # nearest cluster with matching majority arch
            compatible = [j for j in range(k) if majority[j] == arch_ids[n]]
            if compatible:
                labels[n] = compatible[int(np.argmax(sims[n, compatible]))]
            else:
                # seed a spill cluster from the emptiest slot
                j = int(np.argmin(np.bincount(labels, minlength=k)))
                labels[n] = j
                majority[j] = arch_ids[n]

    members = [sorted(np.nonzero(labels == j)[0].tolist()) for j in range(k)]
    return ClusterResult(labels, centroids, sim, members)
