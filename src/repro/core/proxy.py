"""Local-knowledge proxy models (paper §IV.B, Fig. 4).

Within each knowledge domain C_i the uploaded on-device LLMs are
element-wise weight-averaged into a proxy model m̄_i that stands in for
the whole cluster during distillation — this caps the number of teacher
forward passes at K regardless of the device count N (the paper's
scalability answer, Challenge 2).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.utils.pytree import tree_average
from repro.core.clustering import ClusterResult


def build_proxies(device_params: Sequence, clusters: ClusterResult,
                  device_arch: Sequence[int]) -> List[Dict]:
    """Returns one proxy per non-empty cluster:
    {"params", "members", "arch"}  (clusters guaranteed arch-consistent).
    """
    proxies = []
    for j, members in enumerate(clusters.members):
        if not members:
            continue
        archs = {int(device_arch[m]) for m in members}
        assert len(archs) == 1, f"cluster {j} mixes architectures {archs}"
        proxies.append({
            "params": tree_average([device_params[m] for m in members]),
            "members": members,
            "arch": archs.pop(),
            "cluster": j,
        })
    return proxies
