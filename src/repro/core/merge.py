"""Global MoE model merge (paper §IV.D, Fig. 6).

Merge rule:
 * expert i of every MoE block copies the FFN of base model M_i (Eq. 12);
 * embedding / self-attention / output (and norms) are the element-wise
   average over the K base models (Eq. 13);
 * the router (gate) keeps its fresh initialisation — it is trained in
   Phase III.

The MoE config's ``moe_d_ff`` must equal the base models' ``d_ff`` (the
upcycling invariant, Fig. 1).  When there are fewer base models than
experts, clusters are assigned to experts round-robin (each proxy seeds
⌈E/K⌉ experts — noted in DESIGN.md); shared experts are seeded from the
average FFN.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import model as M
from repro.utils.pytree import tree_average


def base_config_of(moe_cfg: ModelConfig, name: str = "") -> ModelConfig:
    """The dense 'MoE base model' config this MoE upcycles from (Fig. 1)."""
    return moe_cfg.replace(
        name=name or (moe_cfg.name + "-base"),
        arch_type="dense",
        n_experts=0, n_shared_experts=0, top_k=0, moe_d_ff=0,
        first_dense_layers=0, n_mtp=0,
        d_ff=moe_cfg.moe_d_ff or moe_cfg.d_ff,
    )


_AVG_TOP = ("embed", "final_norm", "lm_head")
_AVG_BLOCK = ("ln1", "ln2", "ln1_post", "ln2_post", "attn")


def merge_into_moe(key, moe_cfg: ModelConfig,
                   base_params_list: Sequence) -> dict:
    """Builds global MoE params from K dense base models (Fig. 6)."""
    E = moe_cfg.n_experts
    K = len(base_params_list)
    assert K >= 1
    moe_params = M.init_params(key, moe_cfg)
    dtype = jnp.dtype(moe_cfg.dtype)
    avg = tree_average(list(base_params_list))

    # ---- top-level shared layers: average (Eq. 13) ----------------------
    for name in _AVG_TOP:
        if name in moe_params and name in avg:
            moe_params[name] = jax.tree.map(
                lambda a, m: a.astype(m.dtype), avg[name], moe_params[name])

    # ---- per-block: average attention/norms, copy expert FFNs (Eq. 12) --
    blocks = moe_params["blocks"]
    lps = moe_cfg.layers_per_scan
    for i in range(lps):
        sub = blocks[f"sub{i}"]
        asub = avg["blocks"]["sub0"]
        for name in _AVG_BLOCK:
            if name in sub and name in asub:
                sub[name] = jax.tree.map(
                    lambda a, m: a.astype(m.dtype), asub[name], sub[name])
        # experts: (nG, E, D, F) <- base_e (nG, D, F), round-robin over K
        for wname in ("wi_gate", "wi_up", "wo"):
            tgt = sub["moe"][wname]
            for e in range(E):
                src = base_params_list[e % K]["blocks"]["sub0"]["mlp"][wname]
                tgt = tgt.at[:, e].set(src.astype(tgt.dtype))
            sub["moe"][wname] = tgt
        # shared experts: tile the average FFN
        if moe_cfg.n_shared_experts and "shared" in sub["moe"]:
            F = moe_cfg.moe_d_ff or moe_cfg.d_ff
            n_sh = moe_cfg.n_shared_experts
            am = avg["blocks"]["sub0"]["mlp"]
            sh = sub["moe"]["shared"]
            sh["wi_gate"] = jnp.tile(am["wi_gate"], (1, 1, n_sh)).astype(dtype)
            sh["wi_up"] = jnp.tile(am["wi_up"], (1, 1, n_sh)).astype(dtype)
            sh["wo"] = (jnp.tile(am["wo"], (1, n_sh, 1)) / n_sh).astype(dtype)
    moe_params["blocks"] = blocks
    return moe_params
