from repro.utils.pytree import (
    tree_size,
    tree_bytes,
    tree_average,
    tree_zeros_like,
    tree_cast,
    tree_norm,
    flatten_with_paths,
    path_str,
)

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_average",
    "tree_zeros_like",
    "tree_cast",
    "tree_norm",
    "flatten_with_paths",
    "path_str",
]
