"""Small pytree helpers used across the framework (pure JAX, no deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree (respects per-leaf dtype)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_average(trees):
    """Element-wise average of a list of identically-structured pytrees.

    This is the FedAvg / proxy-model operator (paper Fig. 4 and Eq. 13).
    """
    n = len(trees)
    if n == 0:
        raise ValueError("tree_average of empty list")
    if n == 1:
        return trees[0]
    return jax.tree.map(
        lambda *xs: (sum(x.astype(jnp.float32) for x in xs) / n).astype(xs[0].dtype),
        *trees,
    )


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_norm(tree) -> jax.Array:
    """Global L2 norm of a pytree."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def flatten_with_paths(tree):
    """Returns [(path_str, leaf)] for a pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(path), leaf) for path, leaf in flat]


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)
