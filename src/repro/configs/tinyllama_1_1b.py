"""TinyLlama 1.1B — llama2-architecture small model. [arXiv:2401.02385]

Also one of the paper's on-device LLM families (§V.A).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    citation="arXiv:2401.02385",
    n_layers=22,
    d_model=2048,
    n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    tie_embeddings=False,
).validate()
