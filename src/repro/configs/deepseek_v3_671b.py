"""DeepSeek-V3 671B — MLA + 1 shared / 256 routed top-8 MoE + MTP.
[arXiv:2412.19437]

61 layers (first 3 dense FFN @ 18432), d_model=7168; multi-head latent
attention (kv_lora=512, rope=64, nope=128, v=128, q_lora=1536); 256
routed experts (d_ff 2048, top-8) + 1 shared expert; one MTP head.
The MLA latent cache (576 f/token/layer) is what lets this config run
``long_500k`` (DESIGN.md §6).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    citation="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432,             # dense FFN width of the 3 leading layers
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3,
    n_mtp=1,
    tie_embeddings=False,
).validate()
