"""Zamba2-7B — hybrid Mamba2 + shared-attention blocks. [arXiv:2411.15242]

81 Mamba-2 blocks, d_model=3584; ONE shared attention(+MLP) block whose
parameters are reused every 6 blocks (Zamba's parameter-sharing trick —
here without the per-use LoRA deltas of the paper, noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    citation="arXiv:2411.15242",
    n_layers=81,
    d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    shared_attn_every=6,
    rope_theta=10000.0,
    tie_embeddings=True,
).validate()
