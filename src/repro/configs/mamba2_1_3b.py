"""Mamba2-1.3B — attention-free SSD state-space model. [arXiv:2405.21060]

48 SSD blocks, d_model=2048 (d_inner 4096, 64 heads x P=64, N=128).
O(1)-state decode makes long_500k trivial (DESIGN.md §6).  The paper's
FFN-expert distillation does not apply (no FFN experts) — DESIGN.md §4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    citation="arXiv:2405.21060",
    n_layers=48,
    d_model=2048,
    n_heads=0, n_kv_heads=0,
    attn_type="none",
    d_ff=0,
    vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
).validate()
