"""Whisper-small — encoder-decoder audio transformer. [arXiv:2212.04356]

The mel-spectrogram + conv frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings (B, 1500, d_model) to the encoder.  The
original decoder context is 448; long shapes are lowered structurally
(sinusoidal positions), noted in DESIGN.md §6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="encdec",
    citation="arXiv:2212.04356",
    n_layers=12,            # decoder layers
    n_enc_layers=12,
    d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    norm_type="layernorm",
    act="gelu",
    mlp_gated=False,
    pos_embedding="sinusoidal",
    frontend="audio",
    frontend_tokens=1500,
    tie_embeddings=True,
).validate()
