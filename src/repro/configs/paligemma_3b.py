"""PaliGemma-3B — SigLIP vision encoder + Gemma decoder. [arXiv:2407.07726]

The SigLIP ViT + projector frontend is a STUB: ``input_specs()`` provides
(B, 256, d_model) patch embeddings; the decoder (implemented here) is
gemma-1-style: GQA kv=1, GeGLU, embed scaling.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    citation="arXiv:2407.07726",
    n_layers=18,
    d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    act="gelu",
    embed_scale=True,
    frontend="vision",
    frontend_tokens=256,
    tie_embeddings=True,
).validate()
