"""StarCoder2-3B — GQA kv=2, RoPE, layernorm + plain GELU MLP.
[arXiv:2402.19173]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    citation="arXiv:2402.19173",
    n_layers=30,
    d_model=3072,
    n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    norm_type="layernorm",
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
).validate()
