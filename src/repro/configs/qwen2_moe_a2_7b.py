"""Qwen1.5-MoE-A2.7B — 4 shared + 60 routed top-4 MoE.
[hf:Qwen/Qwen1.5-MoE-A2.7B]

This is the paper's case-study-1 global MoE ("Qwen-MoE", 14.3B params,
2.7B active).  60 experts pad to 64 on a 16-way expert-parallel axis
(router logits of pad experts masked to -inf; see repro.models.moe).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=5632,              # shared-expert lane width (4 x 1408)
    vocab_size=151936,
    n_experts=60, n_shared_experts=4, top_k=4, moe_d_ff=1408,
    tie_embeddings=False,
).validate()
