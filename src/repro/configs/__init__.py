from repro.configs.registry import (ALL, ASSIGNED, EXTRA, SHAPES,
                                    InputShape, get_config, list_archs,
                                    supported)

__all__ = ["ALL", "ASSIGNED", "EXTRA", "SHAPES", "InputShape",
           "get_config", "list_archs", "supported"]
