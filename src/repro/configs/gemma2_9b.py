"""Gemma-2 9B — dense, local/global alternating, softcaps. [arXiv:2408.00118]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    citation="arXiv:2408.00118",
    n_layers=42,
    d_model=3584,
    n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attn_pattern=("local", "full"),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norm=True,
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
).validate()
