"""Gemma-2 27B — dense, local/global alternating attention, logit
softcaps, GeGLU, post-block norms. [arXiv:2408.00118]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    arch_type="dense",
    citation="arXiv:2408.00118",
    n_layers=46,
    d_model=4608,
    n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    attn_pattern=("local", "full"),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norm=True,
    embed_scale=True,
    act="gelu",
    tie_embeddings=True,
).validate()
