"""The paper's on-device LLM families (§V.A, Figs. 7/8).

Heterogeneous compact architectures deployable on edge hardware:
GPT-2 / GPT-2-Medium (case study 1), TinyLlama, OLMo-1.2B, BLOOM-1.1B
(case study 2).  TinyLlama is shared with the assigned-arch pool
(configs/tinyllama_1_1b.py).  Positional schemes are adapted to the
substrate (GPT-2 learned-pos and BLOOM ALiBi -> sinusoidal; noted).
"""
from repro.models.config import ModelConfig

GPT2 = ModelConfig(
    name="gpt2", citation="Radford et al. 2019 [19]",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=50257, norm_type="layernorm", act="gelu",
    mlp_gated=False, pos_embedding="sinusoidal", tie_embeddings=True,
).validate()

GPT2_MEDIUM = ModelConfig(
    name="gpt2-medium", citation="Radford et al. 2019 [19]",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=50257, norm_type="layernorm", act="gelu",
    mlp_gated=False, pos_embedding="sinusoidal", tie_embeddings=True,
).validate()

OLMO_1_2B = ModelConfig(
    name="olmo-1.2b", citation="arXiv:2402.00838",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=8192, vocab_size=50304, tie_embeddings=True,
).validate()

BLOOM_1_1B = ModelConfig(
    name="bloom-1.1b", citation="arXiv:2211.05100",
    n_layers=24, d_model=1536, n_heads=16, n_kv_heads=16, head_dim=96,
    d_ff=6144, vocab_size=250880, norm_type="layernorm", act="gelu",
    mlp_gated=False, pos_embedding="sinusoidal", tie_embeddings=True,
).validate()
