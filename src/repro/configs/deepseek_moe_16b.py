"""DeepSeek-MoE-16B-base — the paper's case-study-2 global MoE.
[arXiv:2401.06066; paper §V.A]

28 layers, 64 routed (top-6) + 2 shared experts, moe_d_ff=1408,
first layer dense (d_ff=10944).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    citation="arXiv:2401.06066 (paper case study 2)",
    n_layers=28,
    d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944,
    vocab_size=102400,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense_layers=1,
    tie_embeddings=False,
).validate()
