"""Architecture registry: ``--arch <id>`` resolution + shape policies."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig, reduced

from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B
from repro.configs.gemma2_27b import CONFIG as GEMMA2_27B
from repro.configs.gemma2_9b import CONFIG as GEMMA2_9B
from repro.configs.whisper_small import CONFIG as WHISPER_SMALL
from repro.configs.deepseek_v3_671b import CONFIG as DEEPSEEK_V3_671B
from repro.configs.tinyllama_1_1b import CONFIG as TINYLLAMA_1_1B
from repro.configs.qwen2_moe_a2_7b import CONFIG as QWEN2_MOE_A2_7B
from repro.configs.paligemma_3b import CONFIG as PALIGEMMA_3B
from repro.configs.mamba2_1_3b import CONFIG as MAMBA2_1_3B
from repro.configs.starcoder2_3b import CONFIG as STARCODER2_3B
from repro.configs.deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from repro.configs.device_models import (GPT2, GPT2_MEDIUM, OLMO_1_2B,
                                         BLOOM_1_1B)

# The 10 assigned architectures (the dry-run / roofline matrix).
ASSIGNED: Dict[str, ModelConfig] = {
    "zamba2-7b": ZAMBA2_7B,
    "gemma2-27b": GEMMA2_27B,
    "gemma2-9b": GEMMA2_9B,
    "whisper-small": WHISPER_SMALL,
    "deepseek-v3-671b": DEEPSEEK_V3_671B,
    "tinyllama-1.1b": TINYLLAMA_1_1B,
    "qwen2-moe-a2.7b": QWEN2_MOE_A2_7B,
    "paligemma-3b": PALIGEMMA_3B,
    "mamba2-1.3b": MAMBA2_1_3B,
    "starcoder2-3b": STARCODER2_3B,
}

# Paper-specific + device models.
EXTRA: Dict[str, ModelConfig] = {
    "deepseek-moe-16b": DEEPSEEK_MOE_16B,
    "gpt2": GPT2,
    "gpt2-medium": GPT2_MEDIUM,
    "olmo-1.2b": OLMO_1_2B,
    "bloom-1.1b": BLOOM_1_1B,
}

ALL: Dict[str, ModelConfig] = {**ASSIGNED, **EXTRA}

# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# long_500k policy (DESIGN.md §6): run for sub-quadratic-decode archs,
# skip for pure full-attention dense archs / 448-ctx whisper.
LONG_DECODE_OK = {
    "mamba2-1.3b": "O(1) SSM state",
    "zamba2-7b": "SSM state + shared-attn KV (hybrid)",
    "gemma2-9b": "sliding-window local layers",
    "gemma2-27b": "sliding-window local layers",
    "deepseek-v3-671b": "MLA latent cache (576 f/token/layer)",
}
LONG_DECODE_SKIP = {
    "tinyllama-1.1b": "pure full attention, no windowed variant",
    "starcoder2-3b": "pure full attention, no windowed variant",
    "paligemma-3b": "pure full attention, no windowed variant",
    "qwen2-moe-a2.7b": "pure full attention, no windowed variant",
    "whisper-small": "decoder designed for 448-token context",
}


def supported(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k":
        if arch in LONG_DECODE_OK:
            return True, LONG_DECODE_OK[arch]
        return False, LONG_DECODE_SKIP.get(arch, "unsupported")
    return True, ""


def get_config(name: str, *, variant: str = "full") -> ModelConfig:
    """--arch resolution.  variant: full | reduced."""
    if name not in ALL:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ALL)}")
    cfg = ALL[name]
    if variant == "reduced":
        return reduced(cfg)
    return cfg


def list_archs() -> List[str]:
    return sorted(ASSIGNED)
