"""jnp oracle for the fused gather/scatter-add token movement."""
from __future__ import annotations

import jax.numpy as jnp


def gather_scatter_add_ref(src, src_rows, dst_rows, scale, n_out: int):
    """out[dst_rows[i]] += scale[i] * src[src_rows[i]] in f32."""
    srcf = src.astype(jnp.float32)
    out = jnp.zeros((n_out, src.shape[1]), jnp.float32)
    out = out.at[dst_rows].add(scale.astype(jnp.float32)[:, None]
                               * srcf[src_rows])
    return out.astype(src.dtype)
