"""Shared MoE dispatch/combine: the single definition of routing layout,
capacity accounting and drop semantics for every MoE execution path.

``capacity_positions`` ranks each (token, expert) assignment within its
expert; ``token_dispatch`` / ``token_combine`` move rows between the
flat token array and flat capacity slots.  Both movements are one
``gather_scatter_add`` primitive carrying a ``jax.custom_vjp`` whose
backward is the same primitive with source/destination swapped — so the
Pallas data-movement kernel is trainable end-to-end, mirroring the
custom-VJP pattern of ``kernels/kd_loss/ops.py``.

``use_kernel=False`` selects a pure-XLA ``.at[].add`` implementation
(natively differentiable) for the non-Pallas model configs; both
implementations share the same index/mask computation, so the three
``models/moe.py`` paths agree on which tokens drop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_dispatch.kernel import (fits_vmem,
                                               gather_scatter_add_rows)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def capacity_positions(flat_e, cap: int, valid=None):
    """Rank of each assignment within its expert + keep mask.

    flat_e: (N,) expert ids.  Returns (pos (N,) int32, keep (N,) bool)
    where ``pos`` is the arrival rank among equal expert ids (stable in
    token order — GShard drop semantics) and ``keep = pos < cap``.

    ``valid`` (N,) bool marks assignments that exist at all (serving:
    tokens from live engine slots).  Invalid assignments are ranked in a
    sentinel bucket past every real expert id, so they consume NO
    capacity rank inside any expert — a freed slot's garbage lane can
    never crowd a live token out of an expert — and are always dropped
    (``keep`` is False for them).
    """
    n = flat_e.shape[0]
    key = flat_e
    if valid is not None:
        key = jnp.where(valid, flat_e, jnp.iinfo(flat_e.dtype).max)
    order = jnp.argsort(key, stable=True)
    sorted_e = key[order]
    pos_sorted = jnp.arange(n) - jnp.searchsorted(sorted_e, sorted_e, "left")
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < cap
    if valid is not None:
        keep = keep & valid
    return pos, keep


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _gsa(src, scale, src_rows, dst_rows, n_out, interpret):
    return gather_scatter_add_rows(src, src_rows, dst_rows, scale, n_out,
                                   interpret=interpret)


def _gsa_fwd(src, scale, src_rows, dst_rows, n_out, interpret):
    out = _gsa(src, scale, src_rows, dst_rows, n_out, interpret)
    return out, (src, scale, src_rows, dst_rows)


def _gsa_bwd(n_out, interpret, res, dout):
    src, scale, src_rows, dst_rows = res
    doutf = dout.astype(jnp.float32)
    # transpose of a scatter-add is the same movement, reversed
    dsrc = gather_scatter_add_rows(doutf, dst_rows, src_rows, scale,
                                   src.shape[0], interpret=interpret)
    dscale = jnp.einsum("rd,rd->r", src[src_rows].astype(jnp.float32),
                        doutf[dst_rows])
    zero_i = np.zeros(src_rows.shape, dtype=jax.dtypes.float0)
    return (dsrc.astype(src.dtype), dscale.astype(scale.dtype),
            zero_i, np.zeros(dst_rows.shape, dtype=jax.dtypes.float0))


_gsa.defvjp(_gsa_fwd, _gsa_bwd)


def token_dispatch(xt, flat_tok, slot, keep, n_slots: int, *,
                   use_kernel: bool = True, interpret: bool | None = None):
    """Pack tokens into flat capacity slots: out (n_slots, D) with
    ``out[slot[i]] += xt[flat_tok[i]]`` for kept assignments."""
    if interpret is None:
        interpret = _on_cpu()
    scale = keep.astype(jnp.float32)
    dst = jnp.where(keep, slot, 0).astype(jnp.int32)
    if use_kernel and (interpret
                       or fits_vmem(xt.shape[0], n_slots, xt.shape[1])):
        return _gsa(xt, scale, flat_tok.astype(jnp.int32), dst, n_slots,
                    interpret)
    return jnp.zeros((n_slots, xt.shape[1]), xt.dtype).at[dst].add(
        scale[:, None].astype(xt.dtype) * xt[flat_tok])


def token_combine(y2d, flat_tok, slot, keep, weights, n_tokens: int, *,
                  use_kernel: bool = True, interpret: bool | None = None):
    """Unpack expert outputs back to tokens, applying routing weights:
    out (n_tokens, D) with ``out[flat_tok[i]] += w[i] * y2d[slot[i]]``
    for kept assignments (dropped assignments contribute zero)."""
    if interpret is None:
        interpret = _on_cpu()
    scale = jnp.where(keep, weights, 0.0)
    srcr = jnp.where(keep, slot, 0).astype(jnp.int32)
    if use_kernel and (interpret
                       or fits_vmem(y2d.shape[0], n_tokens, y2d.shape[1])):
        return _gsa(y2d, scale, srcr, flat_tok.astype(jnp.int32), n_tokens,
                    interpret)
    gathered = jnp.where(keep[:, None], y2d[srcr], 0.0)
    return jnp.zeros((n_tokens, y2d.shape[1]), y2d.dtype).at[flat_tok].add(
        gathered * scale[:, None].astype(y2d.dtype))
