"""Fused token permute/unpermute (Pallas TPU) — the MoE dispatch/combine
data movement.

One primitive covers all four movements of a routed MoE layer:

    out[dst_rows[i], :] += scale[i] * src[src_rows[i], :]      i = 0..R-1

* dispatch  = gather tokens, scatter into capacity slots (scale = keep)
* combine   = gather slots, scatter-add into tokens (scale = w * keep)
* their backwards are the same primitive with src/dst swapped.

Row indices and scales ride in SMEM via scalar prefetch; src and the
f32 accumulator live whole in VMEM.  That bounds the kernel to movements
whose src + out fit the VMEM budget — ``ops.token_dispatch`` /
``token_combine`` check ``fits_vmem`` and fall back to the XLA
scatter-add implementation for larger buffers (e.g. the a2a send buffer
at production ep_size; a row-tiled multi-pass variant is a listed
follow-up).  The row loop is a sequential ``fori_loop`` — the scatter
targets are data-dependent, so correctness needs in-order
read-modify-write, and the kernel is DMA-bound regardless.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# v5e-class VMEM is 16 MB; leave headroom for indices + double buffering.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def fits_vmem(n_src: int, n_out: int, d: int) -> bool:
    """Whether src + f32 accumulator fit the kernel's whole-in-VMEM design."""
    return 4 * (n_src + n_out) * d <= VMEM_BUDGET_BYTES


def _gsa_kernel(src_rows_ref, dst_rows_ref, scale_ref, src_ref, out_ref):
    out_ref[...] = jnp.zeros_like(out_ref)

    def body(r, _):
        s = src_rows_ref[r]
        d = dst_rows_ref[r]
        c = scale_ref[r]
        row = pl.load(src_ref, (pl.ds(s, 1), slice(None))).astype(jnp.float32)
        cur = pl.load(out_ref, (pl.ds(d, 1), slice(None)))
        pl.store(out_ref, (pl.ds(d, 1), slice(None)), cur + c * row)
        return 0

    jax.lax.fori_loop(0, src_rows_ref.shape[0], body, 0)


def gather_scatter_add_rows(src, src_rows, dst_rows, scale, n_out: int, *,
                            interpret: bool = False):
    """src: (Ns, D); src_rows/dst_rows: (R,) int32; scale: (R,) -> (n_out, D).

    Accumulates in f32, returns ``src.dtype``.  Out-of-capacity rows are
    expressed as ``scale == 0`` (the row still moves, adds nothing), so
    index arrays never need masking beyond clamping into range.
    """
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(1,),
        in_specs=[pl.BlockSpec(src.shape, lambda i, *refs: (0, 0))],
        out_specs=pl.BlockSpec((n_out, src.shape[1]), lambda i, *refs: (0, 0)),
    )
    out = pl.pallas_call(
        _gsa_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, src.shape[1]), jnp.float32),
        interpret=interpret,
    )(src_rows.astype(jnp.int32), dst_rows.astype(jnp.int32),
      scale.astype(jnp.float32), src)
    return out.astype(src.dtype)
