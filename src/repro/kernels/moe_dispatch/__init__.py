from repro.kernels.moe_dispatch import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
