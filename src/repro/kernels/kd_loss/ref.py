"""jnp oracle for the fused KD loss (dense logits, small shapes only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _softcap(z, cap):
    if cap:
        return jnp.tanh(z / cap) * cap
    return z


def ce_ref(hs, ws, labels, *, softcap: float = 0.0):
    """Returns (ce (T,), correct (T,))."""
    z = _softcap(hs.astype(jnp.float32) @ ws.astype(jnp.float32), softcap)
    lse = jax.nn.logsumexp(z, axis=-1)
    gold = jnp.take_along_axis(z, labels[:, None], axis=-1)[:, 0]
    correct = (jnp.argmax(z, -1) == labels).astype(jnp.float32)
    return lse - gold, correct


def ce_kl_ref(hs, ws, ht, wt, labels, *, tau: float = 1.0,
              softcap_s: float = 0.0, softcap_t: float = 0.0):
    """Returns (ce (T,), kl (T,), correct (T,))."""
    zs = _softcap(hs.astype(jnp.float32) @ ws.astype(jnp.float32), softcap_s)
    zt = _softcap(ht.astype(jnp.float32) @ wt.astype(jnp.float32), softcap_t)
    ce, correct = ce_ref(hs, ws, labels, softcap=softcap_s)
    logp_s = jax.nn.log_softmax(zs / tau, axis=-1)
    logp_t = jax.nn.log_softmax(zt / tau, axis=-1)
    p_t = jnp.exp(logp_t)
    kl = jnp.sum(p_t * (logp_t - logp_s), axis=-1) * tau ** 2
    return ce, kl, correct
