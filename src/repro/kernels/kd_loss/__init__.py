from repro.kernels.kd_loss import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
