"""Fused KD loss (Pallas TPU): CE + KL straight from hidden states.

The distillation server's hot spot: with V up to 256k, materialising
teacher + student logits for a (B, S) batch costs O(B·S·V) HBM traffic
*twice*.  This kernel streams vocab tiles through VMEM and keeps only
O(T) running statistics:

  student CE (raw logits):    m_s, l_s (online logsumexp), gold, argmax
  student KL side (z_s / τ):  m_sτ, l_sτ
  teacher  KL side (z_t / τ): m_tτ, l_tτ, U = Σ e^{z_tτ-m} z_tτ,
                              W = Σ e^{z_tτ-m} z_sτ  (cross term)

Finalisation (last vocab tile):
  CE = lse_s - z_s[label]
  KL = τ² [ (U/l_t - lse_tτ) - (W/l_t - lse_sτ) ]
     = τ² E_{p_t}[ log p_t - log p_s ]

Grid: (nT, nV); vocab tiles are the sequential innermost dimension.
Tiles: hs (Bt, Ds), ws (Ds, Bv), ht (Bt, Dt), wt (Dt, Bv) — two MXU
matmuls per step; VMEM ~ (Bt+Bv)·D·4B, MXU-aligned at Bt=Bv=128.

The backward pass is a vocab-blocked jnp scan (see ops.py custom_vjp) —
mathematically the same streaming pattern, left to XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _softcap(z, cap):
    if cap:
        return jnp.tanh(z / cap) * cap
    return z


def _kd_kernel(hs_ref, ws_ref, ht_ref, wt_ref, lab_ref,
               ce_ref, kl_ref, cor_ref,
               ms_scr, ls_scr, gold_scr, bmax_scr, barg_scr,
               mst_scr, lst_scr, mtt_scr, ltt_scr, u_scr, w_scr, *,
               tau: float, softcap_s: float, softcap_t: float,
               block_v: int, vocab: int, with_teacher: bool):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        ms_scr[...] = jnp.full_like(ms_scr, NEG_INF)
        ls_scr[...] = jnp.zeros_like(ls_scr)
        gold_scr[...] = jnp.zeros_like(gold_scr)
        bmax_scr[...] = jnp.full_like(bmax_scr, NEG_INF)
        barg_scr[...] = jnp.zeros_like(barg_scr)
        mst_scr[...] = jnp.full_like(mst_scr, NEG_INF)
        lst_scr[...] = jnp.zeros_like(lst_scr)
        mtt_scr[...] = jnp.full_like(mtt_scr, NEG_INF)
        ltt_scr[...] = jnp.zeros_like(ltt_scr)
        u_scr[...] = jnp.zeros_like(u_scr)
        w_scr[...] = jnp.zeros_like(w_scr)

    hs = hs_ref[...].astype(jnp.float32)              # (Bt, Ds)
    ws = ws_ref[...].astype(jnp.float32)              # (Ds, Bv)
    zs = _softcap(jax.lax.dot(hs, ws), softcap_s)     # (Bt, Bv)
    v0 = vi * block_v
    vids = v0 + jax.lax.broadcasted_iota(jnp.int32, zs.shape, 1)
    valid = vids < vocab
    zs = jnp.where(valid, zs, NEG_INF)

    # ---- student raw-logit statistics (CE + accuracy) -------------------
    m_prev = ms_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(zs, axis=-1))
    ls_scr[...] = ls_scr[...] * jnp.exp(m_prev - m_new) + \
        jnp.sum(jnp.where(valid, jnp.exp(zs - m_new[:, None]), 0.0), axis=-1)
    ms_scr[...] = m_new
    lab = lab_ref[...]
    hit = vids == lab[:, None]
    gold_scr[...] += jnp.sum(jnp.where(hit, zs, 0.0), axis=-1)
    blk_max = jnp.max(zs, axis=-1)
    blk_arg = v0 + jnp.argmax(zs, axis=-1).astype(jnp.int32)
    better = blk_max > bmax_scr[...]
    barg_scr[...] = jnp.where(better, blk_arg, barg_scr[...])
    bmax_scr[...] = jnp.where(better, blk_max, bmax_scr[...])

    if with_teacher:
        ht = ht_ref[...].astype(jnp.float32)
        wt = wt_ref[...].astype(jnp.float32)
        zt = _softcap(jax.lax.dot(ht, wt), softcap_t)
        zt = jnp.where(valid, zt, NEG_INF)
        zs_t = zs / tau
        zt_t = zt / tau
        # student temperature-side lse
        m_prev = mst_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(zs_t, axis=-1))
        lst_scr[...] = lst_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(
            jnp.where(valid, jnp.exp(zs_t - m_new[:, None]), 0.0), axis=-1)
        mst_scr[...] = m_new
        # teacher-side online stats (lse + U + cross W)
        m_prev = mtt_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(zt_t, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(zt_t - m_new[:, None]), 0.0)
        ltt_scr[...] = ltt_scr[...] * corr + jnp.sum(p, axis=-1)
        u_scr[...] = u_scr[...] * corr + jnp.sum(
            p * jnp.where(valid, zt_t, 0.0), axis=-1)
        w_scr[...] = w_scr[...] * corr + jnp.sum(
            p * jnp.where(valid, zs_t, 0.0), axis=-1)
        mtt_scr[...] = m_new

    @pl.when(vi == nv - 1)
    def _fin():
        lse_s = ms_scr[...] + jnp.log(jnp.maximum(ls_scr[...], 1e-30))
        ce_ref[...] = (lse_s - gold_scr[...]).astype(ce_ref.dtype)
        cor_ref[...] = (barg_scr[...] == lab_ref[...]).astype(cor_ref.dtype)
        if with_teacher:
            lse_st = mst_scr[...] + jnp.log(jnp.maximum(lst_scr[...], 1e-30))
            lse_tt = mtt_scr[...] + jnp.log(jnp.maximum(ltt_scr[...], 1e-30))
            lt = jnp.maximum(ltt_scr[...], 1e-30)
            ez_t = u_scr[...] / lt
            ez_s = w_scr[...] / lt
            kl = (tau ** 2) * ((ez_t - lse_tt) - (ez_s - lse_st))
            kl_ref[...] = kl.astype(kl_ref.dtype)
        else:
            kl_ref[...] = jnp.zeros_like(kl_ref)


def kd_loss_fwd(hs, ws, ht, wt, labels, *, tau: float, softcap_s: float,
                softcap_t: float, block_t: int = 128, block_v: int = 512,
                interpret: bool = False):
    """hs: (T, Ds), ws: (Ds, V), ht: (T, Dt) | None, wt: (Dt, V) | None,
    labels: (T,) -> (ce (T,), kl (T,), correct (T,))."""
    T, Ds = hs.shape
    V = ws.shape[1]
    with_teacher = ht is not None
    if not with_teacher:  # dummies keep the pallas signature uniform
        ht = jnp.zeros((T, 1), hs.dtype)
        wt = jnp.zeros((1, V), hs.dtype)
    Dt = ht.shape[1]
    bt = min(block_t, T)
    bv = min(block_v, V)
    pad_t = (-T) % bt
    pad_v = (-V) % bv
    if pad_t:
        hs = jnp.pad(hs, ((0, pad_t), (0, 0)))
        ht = jnp.pad(ht, ((0, pad_t), (0, 0)))
        labels = jnp.pad(labels, (0, pad_t))
    if pad_v:
        ws = jnp.pad(ws, ((0, 0), (0, pad_v)))
        wt = jnp.pad(wt, ((0, 0), (0, pad_v)))
    nt = hs.shape[0] // bt
    nv = ws.shape[1] // bv

    kern = functools.partial(
        _kd_kernel, tau=tau, softcap_s=softcap_s, softcap_t=softcap_t,
        block_v=bv, vocab=V, with_teacher=with_teacher)
    scr = [pltpu.VMEM((bt,), jnp.float32) for _ in range(4)]
    scr += [pltpu.VMEM((bt,), jnp.int32)]
    scr += [pltpu.VMEM((bt,), jnp.float32) for _ in range(6)]
    ce, kl, cor = pl.pallas_call(
        kern,
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt, Ds), lambda t, v: (t, 0)),
            pl.BlockSpec((Ds, bv), lambda t, v: (0, v)),
            pl.BlockSpec((bt, Dt), lambda t, v: (t, 0)),
            pl.BlockSpec((Dt, bv), lambda t, v: (0, v)),
            pl.BlockSpec((bt,), lambda t, v: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda t, v: (t,)),
            pl.BlockSpec((bt,), lambda t, v: (t,)),
            pl.BlockSpec((bt,), lambda t, v: (t,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((nt * bt,), jnp.float32)] * 3,
        scratch_shapes=scr,
        interpret=interpret,
    )(hs, ws, ht, wt, labels)
    return ce[:T], kl[:T], cor[:T]
