"""Public wrappers with custom VJP.

Forward: the fused Pallas kernel (interpret=True on CPU).
Backward: the same vocab-streaming pattern expressed as a jnp scan over
vocab blocks (two passes: lse statistics, then gradient tiles) — XLA
fuses it tile-by-tile, so the (T, V) logits still never hit HBM whole.

  d CE/d z_s = softmax(z_s) - onehot(label)
  d KL/d z_s = τ · (softmax(z_s/τ) - softmax(z_t/τ))

The teacher side is stop-gradient by construction (no cotangents for
ht / wt) — matching Eq. 10, where the teacher is frozen.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kd_loss.kernel import kd_loss_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _softcap_and_grad(z, cap):
    if not cap:
        return z, jnp.ones_like(z)
    t = jnp.tanh(z / cap)
    return t * cap, 1.0 - t * t


def _lse_stats(h, *, softcap, blocks, vocab, block_v, tau: float = 1.0):
    """Streaming logsumexp over vocab blocks (pad-masked).  Returns (m, l)."""
    T = h.shape[0]
    m = jnp.full((T,), -1e30, jnp.float32)
    l = jnp.zeros((T,), jnp.float32)
    nv = blocks.shape[0]

    def body(carry, inp):
        m, l = carry
        wb, vi = inp
        z, _ = _softcap_and_grad(h @ wb, softcap)
        z = z / tau
        vids = vi * block_v + jnp.arange(z.shape[1])
        z = jnp.where((vids < vocab)[None, :], z, -1e30)
        m_new = jnp.maximum(m, jnp.max(z, -1))
        l = l * jnp.exp(m - m_new) + jnp.sum(jnp.exp(z - m_new[:, None]), -1)
        return (m_new, l), 0

    (m, l), _ = jax.lax.scan(body, (m, l), (blocks, jnp.arange(nv)))
    return m, l


def _split_vocab(w, block_v):
    D, V = w.shape
    pad = (-V) % block_v
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    nv = w.shape[1] // block_v
    return w.T.reshape(nv, block_v, D).transpose(0, 2, 1), pad  # (nv, D, bv)


# ---------------------------------------------------------------------------
# CE only
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _ce(hs, ws, labels, softcap, block_v, interpret):
    ce, _, cor = kd_loss_fwd(hs, ws, None, None, labels, tau=1.0,
                             softcap_s=softcap, softcap_t=0.0,
                             block_v=block_v, interpret=interpret)
    return ce, cor


def _ce_fwd(hs, ws, labels, softcap, block_v, interpret):
    out = _ce(hs, ws, labels, softcap, block_v, interpret)
    return out, (hs, ws, labels)


def _ce_bwd(softcap, block_v, interpret, res, cots):
    hs, ws, labels = res
    dce = cots[0]  # (T,)
    hsf = hs.astype(jnp.float32)
    blocks, pad = _split_vocab(ws.astype(jnp.float32), block_v)
    V = ws.shape[1]
    m, l = _lse_stats(hsf, softcap=softcap, blocks=blocks, vocab=V,
                      block_v=block_v)

    def body(carry, inp):
        dhs, dws_blocks_i = carry
        wb, vi = inp
        z_raw = hsf @ wb
        z, dz_cap = _softcap_and_grad(z_raw, softcap)
        p = jnp.exp(z - m[:, None]) / l[:, None]
        v0 = vi * block_v
        vids = v0 + jnp.arange(z.shape[1])
        onehot = (vids[None, :] == labels[:, None]).astype(jnp.float32)
        valid = (vids < V).astype(jnp.float32)[None, :]
        dz = (p - onehot) * dce[:, None] * dz_cap * valid
        dhs = dhs + dz @ wb.T
        dwb = hsf.T @ dz
        return (dhs, 0), dwb

    nv = blocks.shape[0]
    (dhs, _), dws_blocks = jax.lax.scan(
        body, (jnp.zeros_like(hsf), 0), (blocks, jnp.arange(nv)))
    dws = dws_blocks.transpose(1, 0, 2).reshape(hs.shape[1], -1)[:, :V]
    return dhs.astype(hs.dtype), dws.astype(ws.dtype), None


_ce.defvjp(_ce_fwd, _ce_bwd)


def ce_from_hidden(hh, w, labels, *, softcap: float = 0.0,
                   block_v: int = 512, interpret: bool | None = None):
    """hh: (..., D), labels: (...) -> (nll (...), correct (...))."""
    if interpret is None:
        interpret = _on_cpu()
    shape = labels.shape
    hs = hh.reshape(-1, hh.shape[-1])
    ce, cor = _ce(hs, w, labels.reshape(-1), softcap, block_v, interpret)
    return ce.reshape(shape), cor.reshape(shape)


# ---------------------------------------------------------------------------
# CE + KL
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _ce_kl(hs, ws, ht, wt, labels, tau, softcap_s, softcap_t, block_v,
           interpret):
    return kd_loss_fwd(hs, ws, ht, wt, labels, tau=tau, softcap_s=softcap_s,
                       softcap_t=softcap_t, block_v=block_v,
                       interpret=interpret)


def _ce_kl_fwd(hs, ws, ht, wt, labels, tau, softcap_s, softcap_t, block_v,
               interpret):
    out = _ce_kl(hs, ws, ht, wt, labels, tau, softcap_s, softcap_t, block_v,
                 interpret)
    return out, (hs, ws, ht, wt, labels)


def _ce_kl_bwd(tau, softcap_s, softcap_t, block_v, interpret, res, cots):
    hs, ws, ht, wt, labels = res
    dce, dkl = cots[0], cots[1]
    hsf, htf = hs.astype(jnp.float32), ht.astype(jnp.float32)
    sblocks, _ = _split_vocab(ws.astype(jnp.float32), block_v)
    tblocks, _ = _split_vocab(wt.astype(jnp.float32), block_v)
    V = ws.shape[1]

    # pass 1: statistics (pad-masked)
    m_s, l_s = _lse_stats(hsf, softcap=softcap_s, blocks=sblocks, vocab=V,
                          block_v=block_v)
    m_st, l_st = _lse_stats(hsf, softcap=softcap_s, blocks=sblocks, vocab=V,
                            block_v=block_v, tau=tau)
    m_tt, l_tt = _lse_stats(htf, softcap=softcap_t, blocks=tblocks, vocab=V,
                            block_v=block_v, tau=tau)

    # pass 2: gradient tiles
    def body(dhs, inp):
        wsb, wtb, vi = inp
        zs_raw = hsf @ wsb
        zs, dcap_s = _softcap_and_grad(zs_raw, softcap_s)
        zt, _ = _softcap_and_grad(htf @ wtb, softcap_t)
        p_raw = jnp.exp(zs - m_s[:, None]) / l_s[:, None]
        p_st = jnp.exp(zs / tau - m_st[:, None]) / l_st[:, None]
        p_tt = jnp.exp(zt / tau - m_tt[:, None]) / l_tt[:, None]
        v0 = vi * block_v
        vids = v0 + jnp.arange(zs.shape[1])
        onehot = (vids[None, :] == labels[:, None]).astype(jnp.float32)
        valid = (vids < V).astype(jnp.float32)[None, :]
        dz = ((p_raw - onehot) * dce[:, None]
              + tau * (p_st - p_tt) * dkl[:, None]) * dcap_s * valid
        dhs = dhs + dz @ wsb.T
        dwb = hsf.T @ dz
        return dhs, dwb

    nv = sblocks.shape[0]
    dhs, dws_blocks = jax.lax.scan(
        body, jnp.zeros_like(hsf), (sblocks, tblocks, jnp.arange(nv)))
    dws = dws_blocks.transpose(1, 0, 2).reshape(hs.shape[1], -1)[:, :V]
    # teacher is frozen (Eq. 10): zero cotangents
    return (dhs.astype(hs.dtype), dws.astype(ws.dtype),
            jnp.zeros_like(ht), jnp.zeros_like(wt), None)


_ce_kl.defvjp(_ce_kl_fwd, _ce_kl_bwd)


def ce_kl_from_hidden(hh_s, w_s, hh_t, w_t, labels, *, tau: float = 1.0,
                      softcap_s: float = 0.0, softcap_t: float = 0.0,
                      block_v: int = 512, interpret: bool | None = None):
    """(..., Ds) student + (..., Dt) teacher hiddens -> (ce, kl, correct)."""
    if interpret is None:
        interpret = _on_cpu()
    shape = labels.shape
    ce, kl, cor = _ce_kl(hh_s.reshape(-1, hh_s.shape[-1]), w_s,
                         hh_t.reshape(-1, hh_t.shape[-1]), w_t,
                         labels.reshape(-1), tau, softcap_s, softcap_t,
                         block_v, interpret)
    return ce.reshape(shape), kl.reshape(shape), cor.reshape(shape)
