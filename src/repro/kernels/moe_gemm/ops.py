"""Public wrappers for the grouped expert FFN kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_gemm.kernel import grouped_ffn_ecd
from repro.kernels.moe_gemm import ref as _ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "interpret"))
def grouped_ffn(x, wg, wu, wo, *, act: str = "silu", block_c: int = 128,
                block_f: int = 128, interpret: bool | None = None):
    """Fixed-capacity grouped FFN — drop-in for the a2a expert compute."""
    if interpret is None:
        interpret = _on_cpu()
    return grouped_ffn_ecd(x, wg, wu, wo, act=act, block_c=block_c,
                           block_f=block_f, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("act", "interpret"))
def moe_ffn(xt, w, idx, wg, wu, wo, *, act: str = "silu",
            interpret: bool | None = None):
    """Routed token-level MoE for the single-device path: sorts tokens by
    expert into capacity buffers, runs the grouped kernel, scatters back."""
    if interpret is None:
        interpret = _on_cpu()
    T, D = xt.shape
    k = idx.shape[1]
    E = wg.shape[0]
    cap = max(-(-T * k // E) * 2, 8)  # generous static capacity
    flat_e = idx.reshape(-1)
    flat_w = w.reshape(-1)
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos_sorted = jnp.arange(T * k) - jnp.searchsorted(sorted_e, sorted_e, "left")
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < cap
    buf = jnp.zeros((E, cap, D), xt.dtype)
    buf = buf.at[flat_e, jnp.where(keep, pos, 0)].add(
        jnp.where(keep, 1.0, 0.0)[:, None].astype(xt.dtype) * xt[flat_tok])
    y = grouped_ffn_ecd(buf, wg, wu, wo, act=act, interpret=interpret)
    gathered = y[flat_e, jnp.where(keep, pos, 0)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((T, D), xt.dtype).at[flat_tok].add(
        gathered * flat_w[:, None].astype(xt.dtype))
    return out
