"""Public wrappers for the grouped expert FFN kernel — trainable.

``grouped_ffn`` carries a ``jax.custom_vjp`` (the pattern proven in
``kernels/kd_loss/ops.py``): the forward is the fused Pallas kernel, the
backward is expressed as grouped GEMMs (the ``grouped_matmul`` kernel,
same contraction structure as the forward) through the gated-activation
chain:

    g = x @ wg          u = x @ wu          h = act(g) * u
    dh = dy @ woᵀ       (dg, du) = vjp of act(g)*u at dh
    dx  = dg @ wgᵀ + du @ wuᵀ
    dwg = xᵀ @ dg       dwu = xᵀ @ du       dwo = hᵀ @ dy

g/u/h are recomputed in the backward (activation recomputation), so the
forward saves only its inputs.  ``moe_ffn`` composes the shared fused
dispatch/combine utility (``kernels/moe_dispatch``) with ``grouped_ffn``
and is therefore differentiable end-to-end in tokens, routing weights
and all three expert weight tensors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_gemm.kernel import grouped_ffn_ecd, grouped_matmul
from repro.kernels.moe_dispatch.ops import (capacity_positions,
                                            token_combine, token_dispatch)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _gated_act(act: str, g, u):
    a = jax.nn.gelu(g, approximate=True) if act == "gelu" else jax.nn.silu(g)
    return a * u


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _grouped_ffn(x, wg, wu, wo, act, blocks, interpret):
    return grouped_ffn_ecd(x, wg, wu, wo, act=act, block_c=blocks[0],
                           block_f=blocks[1], interpret=interpret)


def _grouped_ffn_fwd(x, wg, wu, wo, act, blocks, interpret):
    out = _grouped_ffn(x, wg, wu, wo, act, blocks, interpret)
    return out, (x, wg, wu, wo)


def _grouped_ffn_bwd(act, blocks, interpret, res, dy):
    x, wg, wu, wo = res
    gmm = functools.partial(grouped_matmul, interpret=interpret)
    tr = lambda a: jnp.swapaxes(a, -1, -2)
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    g = gmm(xf, wg.astype(jnp.float32))          # (E, C, F)
    u = gmm(xf, wu.astype(jnp.float32))
    h, h_vjp = jax.vjp(functools.partial(_gated_act, act), g, u)
    dh = gmm(dyf, tr(wo.astype(jnp.float32)))    # (E, C, F)
    dg, du = h_vjp(dh)
    dx = (gmm(dg, tr(wg.astype(jnp.float32)))
          + gmm(du, tr(wu.astype(jnp.float32))))
    dwg = gmm(tr(xf), dg)                        # (E, D, F)
    dwu = gmm(tr(xf), du)
    dwo = gmm(tr(h), dyf)                        # (E, F, D)
    return (dx.astype(x.dtype), dwg.astype(wg.dtype), dwu.astype(wu.dtype),
            dwo.astype(wo.dtype))


_grouped_ffn.defvjp(_grouped_ffn_fwd, _grouped_ffn_bwd)


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "interpret"))
def grouped_ffn(x, wg, wu, wo, *, act: str = "silu", block_c: int = 128,
                block_f: int = 128, interpret: bool | None = None):
    """Fixed-capacity grouped FFN — drop-in for the a2a expert compute."""
    if interpret is None:
        interpret = _on_cpu()
    return _grouped_ffn(x, wg, wu, wo, act, (block_c, block_f), interpret)


@functools.partial(jax.jit, static_argnames=("act", "interpret"))
def moe_ffn(xt, w, idx, wg, wu, wo, *, act: str = "silu",
            interpret: bool | None = None):
    """Routed token-level MoE for the single-device path: fused dispatch
    into capacity buffers, grouped kernel, fused weighted combine."""
    if interpret is None:
        interpret = _on_cpu()
    T, D = xt.shape
    k = idx.shape[1]
    E = wg.shape[0]
    cap = max(-(-T * k // E) * 2, 8)  # generous static capacity
    flat_e = idx.reshape(-1)
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    pos, keep = capacity_positions(flat_e, cap)
    slot = flat_e * cap + pos
    buf = token_dispatch(xt, flat_tok, slot, keep, E * cap,
                         interpret=interpret)
    y = _grouped_ffn(buf.reshape(E, cap, D), wg, wu, wo, act, (128, 128),
                     interpret)
    out = token_combine(y.reshape(E * cap, D), flat_tok, slot, keep,
                        w.reshape(-1), T, interpret=interpret)
    return out.astype(xt.dtype)
