from repro.kernels.moe_gemm import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
