"""Grouped expert FFN (Pallas TPU) — the MoE compute hot spot.

Computes, for every expert e in the local shard:

    y[e] = ( act(x[e] @ wg[e]) * (x[e] @ wu[e]) ) @ wo[e]

with x: (E, C, D) fixed-capacity token buffers (the all-to-all layout of
``repro.models.moe``) and SwiGLU/GeGLU weights (E, D, F) / (E, F, D).

Grid: (E, nC, nF).  The innermost F dimension is sequential; a (Bc, D)
f32 accumulator in VMEM scratch integrates each F-tile's contribution to
the output (y is linear in the hidden h, so hidden tiles never need to
be resident together).  Tiles:

  x  : (1, Bc, D)  indexed (e, c)
  wg : (1, D, Bf)  indexed (e, f)     wu: same
  wo : (1, Bf, D)  indexed (e, f)
  y  : (1, Bc, D)  indexed (e, c)

VMEM working set = Bc*D + 2*D*Bf + Bf*D + Bc*Bf + Bc*D(acc); with
Bc=Bf=128 and D=8192 this is ~8.5 MB — inside a v5e's 16 MB VMEM budget,
with MXU-aligned (128) matmul dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ffn_kernel(x_ref, wg_ref, wu_ref, wo_ref, y_ref, acc_scr, *, act: str):
    fi = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)         # (Bc, D)
    wg = wg_ref[0].astype(jnp.float32)       # (D, Bf)
    wu = wu_ref[0].astype(jnp.float32)
    wo = wo_ref[0].astype(jnp.float32)       # (Bf, D)
    g = jax.lax.dot(x, wg)                   # (Bc, Bf)
    if act == "gelu":
        g = jax.nn.gelu(g, approximate=True)
    else:
        g = jax.nn.silu(g)
    h = g * jax.lax.dot(x, wu)
    acc_scr[...] += jax.lax.dot(h, wo)       # (Bc, D)

    @pl.when(fi == nf - 1)
    def _fin():
        y_ref[0] = acc_scr[...].astype(y_ref.dtype)


def _matmul_kernel(a_ref, b_ref, y_ref, acc_scr):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = a_ref[0].astype(jnp.float32)         # (Bm, Bk)
    b = b_ref[0].astype(jnp.float32)         # (Bk, Bn)
    acc_scr[...] += jax.lax.dot(a, b)

    @pl.when(ki == nk - 1)
    def _fin():
        y_ref[0] = acc_scr[...].astype(y_ref.dtype)


def grouped_matmul(a, b, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 512, interpret: bool = False,
                   out_dtype=None):
    """Per-expert batched GEMM: a (E, M, K) @ b (E, K, N) -> (E, M, N).

    The grouped-GEMM building block for the MoE backward pass — grid
    (E, nM, nN, nK) with a sequential K dimension accumulating into an
    f32 VMEM scratch, the same contraction structure as the forward
    ``grouped_ffn_ecd`` kernel.
    """
    E, M, K = a.shape
    N = b.shape[-1]
    bm = min(block_m, M)
    bn = min(block_n, N)
    bk = min(block_k, K)
    pad_m = (-M) % bm
    pad_n = (-N) % bn
    pad_k = (-K) % bk
    if pad_m or pad_k:
        a = jnp.pad(a, ((0, 0), (0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        b = jnp.pad(b, ((0, 0), (0, pad_k), (0, pad_n)))
    nm = a.shape[1] // bm
    nn = b.shape[2] // bn
    nk = a.shape[2] // bk
    out_dtype = out_dtype or a.dtype

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(E, nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, m, n, k: (e, m, k)),
            pl.BlockSpec((1, bk, bn), lambda e, m, n, k: (e, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, m, n, k: (e, m, n)),
        out_shape=jax.ShapeDtypeStruct((E, nm * bm, nn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:, :M, :N]


def grouped_ffn_ecd(x, wg, wu, wo, *, act: str = "silu", block_c: int = 128,
                    block_f: int = 128, interpret: bool = False):
    """x: (E, C, D); wg/wu: (E, D, F); wo: (E, F, D) -> (E, C, D)."""
    E, C, D = x.shape
    F = wg.shape[-1]
    bc = min(block_c, C)
    bf = min(block_f, F)
    pad_c = (-C) % bc
    pad_f = (-F) % bf
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    if pad_f:
        wg = jnp.pad(wg, ((0, 0), (0, 0), (0, pad_f)))
        wu = jnp.pad(wu, ((0, 0), (0, 0), (0, pad_f)))
        wo = jnp.pad(wo, ((0, 0), (0, pad_f), (0, 0)))
    nc = x.shape[1] // bc
    nf = wg.shape[-1] // bf

    out = pl.pallas_call(
        functools.partial(_ffn_kernel, act=act),
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, D), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, D, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, D, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, bf, D), lambda e, c, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, D), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, nc * bc, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, D), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wo)
    return out[:, :C]
