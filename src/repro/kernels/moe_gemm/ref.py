"""jnp oracle for the grouped expert FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_ffn_ref(x, wg, wu, wo, *, act: str = "silu"):
    """x: (E, C, D); wg/wu: (E, D, F); wo: (E, F, D) -> (E, C, D)."""
    xf = x.astype(jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", xf, wg.astype(jnp.float32))
    g = jax.nn.gelu(g, approximate=True) if act == "gelu" else jax.nn.silu(g)
    h = g * jnp.einsum("ecd,edf->ecf", xf, wu.astype(jnp.float32))
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(jnp.float32))
    return y.astype(x.dtype)


def grouped_ffn_bwd_ref(x, wg, wu, wo, dy, *, act: str = "silu"):
    """Explicit-chain reference backward for ``grouped_ffn``.

    Returns (dx, dwg, dwu, dwo) in f32 — the oracle the custom-VJP
    grouped-GEMM backward is tested against (independent of jax.grad).
    """
    xf = x.astype(jnp.float32)
    wgf, wuf, wof = (t.astype(jnp.float32) for t in (wg, wu, wo))
    dyf = dy.astype(jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", xf, wgf)
    u = jnp.einsum("ecd,edf->ecf", xf, wuf)
    if act == "gelu":
        a = jax.nn.gelu(g, approximate=True)
        # d/dg of tanh-approx gelu
        c = jnp.sqrt(2.0 / jnp.pi)
        inner = c * (g + 0.044715 * g**3)
        t = jnp.tanh(inner)
        da = 0.5 * (1.0 + t) + 0.5 * g * (1.0 - t * t) * c * (
            1.0 + 3 * 0.044715 * g * g)
    else:
        s = jax.nn.sigmoid(g)
        a = g * s
        da = s * (1.0 + g * (1.0 - s))
    h = a * u
    dh = jnp.einsum("ecd,efd->ecf", dyf, wof)
    dg = dh * u * da
    du = dh * a
    dx = (jnp.einsum("ecf,edf->ecd", dg, wgf)
          + jnp.einsum("ecf,edf->ecd", du, wuf))
    dwg = jnp.einsum("ecd,ecf->edf", xf, dg)
    dwu = jnp.einsum("ecd,ecf->edf", xf, du)
    dwo = jnp.einsum("ecf,ecd->efd", h, dyf)
    return dx, dwg, dwu, dwo


def moe_ffn_ref(xt, w, idx, wg, wu, wo, *, act: str = "silu"):
    """Token-level routed MoE oracle (computes all experts, combines).

    xt: (T, D); w: (T, k) routing weights; idx: (T, k) expert ids;
    wg/wu: (E, D, F); wo: (E, F, D).
    """
    E = wg.shape[0]
    xf = xt.astype(jnp.float32)
    g = jnp.einsum("td,edf->etf", xf, wg.astype(jnp.float32))
    g = jax.nn.gelu(g, approximate=True) if act == "gelu" else jax.nn.silu(g)
    h = g * jnp.einsum("td,edf->etf", xf, wu.astype(jnp.float32))
    y_all = jnp.einsum("etf,efd->etd", h, wo.astype(jnp.float32))
    one_hot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    comb = jnp.einsum("tk,tke->te", w.astype(jnp.float32), one_hot)
    return jnp.einsum("te,etd->td", comb, y_all).astype(xt.dtype)
