"""jnp oracle for the grouped expert FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_ffn_ref(x, wg, wu, wo, *, act: str = "silu"):
    """x: (E, C, D); wg/wu: (E, D, F); wo: (E, F, D) -> (E, C, D)."""
    xf = x.astype(jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", xf, wg.astype(jnp.float32))
    g = jax.nn.gelu(g, approximate=True) if act == "gelu" else jax.nn.silu(g)
    h = g * jnp.einsum("ecd,edf->ecf", xf, wu.astype(jnp.float32))
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(jnp.float32))
    return y.astype(x.dtype)


def moe_ffn_ref(xt, w, idx, wg, wu, wo, *, act: str = "silu"):
    """Token-level routed MoE oracle (computes all experts, combines).

    xt: (T, D); w: (T, k) routing weights; idx: (T, k) expert ids;
    wg/wu: (E, D, F); wo: (E, F, D).
    """
    E = wg.shape[0]
    xf = xt.astype(jnp.float32)
    g = jnp.einsum("td,edf->etf", xf, wg.astype(jnp.float32))
    g = jax.nn.gelu(g, approximate=True) if act == "gelu" else jax.nn.silu(g)
    h = g * jnp.einsum("td,edf->etf", xf, wu.astype(jnp.float32))
    y_all = jnp.einsum("etf,efd->etd", h, wo.astype(jnp.float32))
    one_hot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    comb = jnp.einsum("tk,tke->te", w.astype(jnp.float32), one_hot)
    return jnp.einsum("te,etd->td", comb, y_all).astype(xt.dtype)
