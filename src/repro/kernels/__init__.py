"""Pallas TPU kernels for the pipeline's compute hot spots.

Each kernel ships three files:
  kernel.py - ``pl.pallas_call`` body with explicit BlockSpec VMEM tiling,
  ops.py    - jit-able public wrapper (interpret=True on CPU),
  ref.py    - pure-jnp oracle the tests sweep against.

Kernels:
  flash_attention - causal/windowed/softcapped blocked attention
                    (Gemma-2 local+global; prefill hot spot).
  moe_gemm        - grouped expert FFN (E, cap, D) x (E, D, F) for the
                    all-to-all expert-parallel MoE layer; custom-VJP
                    backward as grouped GEMMs (trainable).
  moe_dispatch    - fused token permute/unpermute (gather/scatter-add)
                    shared by all MoE execution paths; custom VJP.
  ssd_scan        - Mamba-2 SSD chunked scan (intra-chunk quadratic +
                    carried state).
  kd_loss         - fused CE + KL over large vocabularies straight from
                    hidden states (the KD server hot spot; never
                    materialises (T, V) logits in HBM).
  paged_attn      - block-paged decode attention: the per-slot block
                    table is scalar-prefetched so each grid cell DMAs
                    exactly the KV pool rows its slot owns (serving).
"""
