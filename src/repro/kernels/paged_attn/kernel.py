"""Paged decode attention for TPU (Pallas, scalar-prefetched block table).

A C-token query chunk per slot attends over a block-paged KV pool
without ever gathering a contiguous per-slot cache in HBM: the per-slot
block table is a **scalar-prefetch** operand, so the k/v BlockSpec index
maps read ``bt[b, j]`` and DMA exactly the pool rows the slot owns.
C=1 is the classic decode step; C>1 serves chunked prefill and the
speculative-decode verify chunk (queries occupy the CONTIGUOUS positions
``pos[b] .. pos[b] + C - 1`` — ``pos`` is the FIRST query's position).

Grid: (B, KH, nbt) — the innermost (table-entry) dimension is sequential
on TPU, so the online-softmax accumulators persist in VMEM scratch
across j-steps, exactly like the flash kernel's k-dimension.

BlockSpec tiling (all VMEM):
  q    : (1, 1, C, G, Dq) indexed (b, h)          — G = H // KH query heads
  k,v  : (1, bl, 1, D*)   indexed (bt[b, j], h)   — the paged indirection
  out  : (1, 1, C, G, Dv) indexed (b, h)

Blocks whose first row lies beyond the LAST query's position (or
entirely left of the sliding window) are skipped with ``pl.when`` — a
slot only pays for the blocks it has actually filled, which is the whole
point of paging.  Within a visible block, per-query causal/window masks
zero the probability mass directly (a block can be visible to the chunk
but fully masked for an individual query row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                  scale: float, window: int, softcap: float,
                  block_len: int, n_q: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    p0 = pos_ref[b]
    base = j * block_len
    # block holds at least one position in range of SOME query
    visible = base <= p0 + n_q - 1
    if window:
        visible = visible & (base + block_len - 1 > p0 - window)

    @pl.when(visible)
    def _compute():
        C, G = m_scr.shape
        q = q_ref[0, 0].astype(jnp.float32)        # (C, G, Dq)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (bl, Dq)
        v = v_ref[0, :, 0].astype(jnp.float32)     # (bl, Dv)
        if quantized:
            # dequantize the DMA'd pool rows in-register: per-(position,
            # kv-head) scales ride the same block-table indirection
            k = k * ks_ref[0, :, 0][:, None]       # (bl,) scales
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q.reshape(C * G, -1), k, (((1,), (1,)), ((), ()))
        ).reshape(C, G, block_len) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        qpos = p0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ok = kpos <= qpos
        if window:
            ok = ok & (kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # mask the probabilities, not just the scores: a query row with
        # no visible position yet has m_new == NEG_INF, and
        # exp(NEG_INF - NEG_INF) would be 1, not 0
        p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
            p.reshape(C * G, block_len), v, (((1,), (0,)), ((), ()))
        ).reshape(C, G, -1)
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[..., None]).astype(o_ref.dtype)


def paged_attention_bhgd(q, k_pool, v_pool, block_table, pos, *,
                         scale: float, window: int, softcap: float,
                         interpret: bool = False, k_scale=None,
                         v_scale=None, out_dtype=None):
    """q: (B, KH, C, G, Dq); pools: (n_blocks, bl, KH, D*);
    block_table: (B, nbt) int32; pos: (B,) int32 position of the FIRST
    query (queries sit at pos .. pos + C - 1) -> (B, KH, C, G, Dv).

    ``k_scale``/``v_scale`` (n_blocks, bl, KH) float32 mark a quantized
    pool (int8/fp8 rows); they ride the same block-table indirection and
    the kernel dequantizes each DMA'd row in-register — no extra HBM
    round-trip.  ``out_dtype`` overrides the output dtype (required when
    the pool dtype is the quantized storage dtype)."""
    B, KH, C, G, Dq = q.shape
    bl = k_pool.shape[1]
    Dv = v_pool.shape[-1]
    nbt = block_table.shape[1]
    quantized = k_scale is not None
    if out_dtype is None:
        out_dtype = v_pool.dtype

    kern = functools.partial(_paged_kernel, scale=scale, window=window,
                             softcap=softcap, block_len=bl, n_q=C,
                             quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, C, G, Dq),
                     lambda b, h, j, bt, pos: (b, h, 0, 0, 0)),
        pl.BlockSpec((1, bl, 1, Dq),
                     lambda b, h, j, bt, pos: (bt[b, j], 0, h, 0)),
        pl.BlockSpec((1, bl, 1, Dv),
                     lambda b, h, j, bt, pos: (bt[b, j], 0, h, 0)),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bl, 1),
                         lambda b, h, j, bt, pos: (bt[b, j], 0, h)),
            pl.BlockSpec((1, bl, 1),
                         lambda b, h, j, bt, pos: (bt[b, j], 0, h)),
        ]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KH, nbt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, C, G, Dv),
                               lambda b, h, j, bt, pos: (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, G), jnp.float32),
            pltpu.VMEM((C, G), jnp.float32),
            pltpu.VMEM((C, G, Dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, C, G, Dv), out_dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), pos.astype(jnp.int32), *operands)
