"""Pure-jnp oracle for paged decode attention (gather + dense scores).

Layout contract (shared with the kernel and ``layers.attention_decode``):
logical position ``j`` of slot ``b`` lives in pool row
``block_table[b, j // block_len]`` at offset ``j % block_len``, so the
gathered-and-flattened view indexes by logical position directly.
Table entries past a slot's allocated blocks point at the trash block 0;
their rows sit above the query positions and are masked.  ``pos`` is the
FIRST query's position; the C chunk queries sit at ``pos .. pos+C-1``
with per-query causal/window masks (in-chunk causality).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def paged_attention_ref(q, k_pool, v_pool, block_table, pos, *,
                        window: int = 0, softcap: float = 0.0, scale=None,
                        k_scale=None, v_scale=None, out_dtype=None):
    """q: (B, C, H, Dq); pools: (n_blocks, block_len, KH, D*);
    block_table: (B, nbt) int32; pos: (B,) int32 -> (B, C, H, Dv).

    ``k_scale``/``v_scale`` (n_blocks, block_len, KH) mark quantized
    pools: the gathered views are dequantized per row before the dense
    scores, mirroring the kernel's in-register dequant."""
    B, C, H, Dq = q.shape
    KH = k_pool.shape[2]
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(Dq)
    if out_dtype is None:
        out_dtype = v_pool.dtype
    kg = k_pool[block_table].reshape((B, -1) + k_pool.shape[2:])
    vg = v_pool[block_table].reshape((B, -1) + v_pool.shape[2:])
    if k_scale is not None:
        ksg = k_scale[block_table].reshape((B, -1) + k_scale.shape[2:])
        vsg = v_scale[block_table].reshape((B, -1) + v_scale.shape[2:])
        kg = kg.astype(jnp.float32) * ksg[..., None].astype(jnp.float32)
        vg = vg.astype(jnp.float32) * vsg[..., None].astype(jnp.float32)
    S = kg.shape[1]
    qr = q.reshape(B, C, KH, G, Dq)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr.astype(jnp.float32),
                   kg.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(S)[None, None, :]                     # (1, 1, S)
    qpos = pos[:, None, None] + jnp.arange(C)[None, :, None]  # (B, C, 1)
    ok = kpos <= qpos
    if window:
        ok = ok & (kpos > qpos - window)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, vg.astype(jnp.float32))
    return o.reshape(B, C, H, vg.shape[-1]).astype(out_dtype)
