"""Public wrapper: GQA layout handling + CPU interpret fallback.

Decode-only (no VJP): the paged pool is serving state, never trained
through.  ``layers.attention_decode`` selects this op under
``cfg.use_pallas`` after inserting the chunk's k/v into the pool; the
engine guarantees every table entry is a valid pool row (trash block 0
for unallocated tail entries), so the kernel needs no bounds handling
beyond the ``pos`` mask.  C=1 is the decode step; C>1 serves chunked
prefill and the speculative verify chunk — queries must occupy the
contiguous positions ``pos .. pos + C - 1``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.paged_attn.kernel import paged_attention_bhgd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "interpret", "out_dtype"))
def paged_decode_attention(q, k_pool, v_pool, block_table, pos, *,
                           window: int = 0, softcap: float = 0.0,
                           scale: float | None = None,
                           interpret: bool | None = None,
                           k_scale=None, v_scale=None, out_dtype=None):
    """q: (B, C, H, Dq); pools: (n_blocks, block_len, KH, D*);
    block_table: (B, nbt); pos: (B,) position of the FIRST query
    (queries are consecutive) -> (B, C, H, Dv).

    GQA stays grouped: each (slot, kv-head) grid cell attends its
    H // KH query heads (for all C chunk positions) against one DMA of
    the head's pool rows.

    Quantized pools (int8/fp8 under a ``CachePolicy``) pass their
    per-(position, kv-head) float32 ``k_scale``/``v_scale`` pools
    (n_blocks, block_len, KH); dequant happens inside the kernel on the
    DMA'd rows.  ``out_dtype`` (static) names the activation dtype to
    produce — mandatory for quantized pools, where ``v_pool.dtype``
    would otherwise leak int8 into the residual stream.
    """
    if interpret is None:
        interpret = _on_cpu()
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    B, C, H, Dq = q.shape
    KH = k_pool.shape[2]
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(Dq)
    qr = q.reshape(B, C, KH, G, Dq).transpose(0, 2, 1, 3, 4)  # (B,KH,C,G,Dq)
    out = paged_attention_bhgd(qr, k_pool, v_pool,
                               jnp.asarray(block_table, jnp.int32),
                               jnp.asarray(pos, jnp.int32), scale=scale,
                               window=window, softcap=softcap,
                               interpret=interpret, k_scale=k_scale,
                               v_scale=v_scale, out_dtype=out_dtype)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, C, H, v_pool.shape[-1])
