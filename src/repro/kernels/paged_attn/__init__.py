from repro.kernels.paged_attn import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
