from repro.kernels.flash_attention import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
