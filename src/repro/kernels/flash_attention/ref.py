"""Pure-jnp oracle for flash attention (dense scores, small shapes only)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale=None):
    """q: (BH, Sq, D), k/v: (BH, Sk, D) -> (BH, Sq, D)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window:
        ok = ok & (kpos > qpos - window)
    s = jnp.where(ok[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(v.dtype)
