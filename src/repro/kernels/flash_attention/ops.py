"""Public wrapper: GQA layout handling + CPU interpret fallback.

Carries a ``jax.custom_vjp`` so ``use_pallas=True`` models can train
end-to-end: the forward runs the Pallas kernel, the backward recomputes
attention through the dense jnp reference and differentiates that
(O(Sq*Sk) scores in the backward only; a flash backward kernel is a
listed perf follow-up).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fa_bhsd(qb, kb, vb, causal, window, softcap, blocks, interpret):
    return flash_attention_bhsd(
        qb, kb, vb, causal=causal, window=window, softcap=softcap,
        scale=1.0 / math.sqrt(qb.shape[-1]), block_q=blocks[0],
        block_k=blocks[1], interpret=interpret)


def _fa_bhsd_fwd(qb, kb, vb, causal, window, softcap, blocks, interpret):
    out = _fa_bhsd(qb, kb, vb, causal, window, softcap, blocks, interpret)
    return out, (qb, kb, vb)


def _fa_bhsd_bwd(causal, window, softcap, blocks, interpret, res, dy):
    qb, kb, vb = res
    _, vjp = jax.vjp(functools.partial(attention_ref, causal=causal,
                                       window=window, softcap=softcap),
                     qb, kb, vb)
    return vjp(dy)


_fa_bhsd.defvjp(_fa_bhsd_fwd, _fa_bhsd_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, H, D), k/v: (B, Sk, KH, D) -> (B, Sq, H, D).

    GQA: kv heads are repeated to H inside the wrapper (the kernel is
    MHA-layout; a grouped-query kernel variant is a listed perf follow-up).
    """
    if interpret is None:
        interpret = _on_cpu()
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    if KH != H:
        rep = H // KH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    out = _fa_bhsd(qb, kb, vb, causal, window, softcap, (block_q, block_k),
                   interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
