"""Blocked flash attention for TPU (Pallas).

Grid: (batch*heads, n_q_blocks, n_k_blocks) — the innermost (k) dimension
is sequential on TPU, so the online-softmax accumulators (running max,
denominator, output) live in VMEM scratch and persist across k-steps.

BlockSpec tiling (all VMEM):
  q   : (1, Bq, D)   indexed (bh, qi)
  k,v : (1, Bk, D)   indexed (bh, ki)
  out : (1, Bq, D)   indexed (bh, qi)

Supports causal masking, sliding windows (Gemma-2 local layers) and
attention logit soft-capping.  Fully-masked (q, k) block pairs are
skipped with ``pl.when`` — on real hardware this prunes ~half the blocks
for causal prefill and all out-of-window blocks for local layers.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 block_q: int, block_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # static-ish block-level visibility (program ids are dynamic, so this
    # is a pl.when guard rather than a python `if`)
    q_start = qi * block_q
    k_start = ki * block_k
    visible = jnp.bool_(True)
    if causal:
        visible = visible & (k_start <= q_start + block_q - 1)
    if window:
        visible = visible & (k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (Bq, D)
        k = k_ref[0].astype(jnp.float32)              # (Bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < seq_k
        if causal:
            ok = ok & (kpos <= qpos)
        if window:
            ok = ok & (kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, window: int,
                         softcap: float, scale: float,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: (BH, Sq, D), k/v: (BH, Sk, D).  Head dim D should be MXU-friendly
    (multiple of 128 ideally; smaller dims still work, padded by Mosaic)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[1] // bq
    nk = k.shape[1] // bk

    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, seq_q=Sq, seq_k=Sk)
    out = pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
