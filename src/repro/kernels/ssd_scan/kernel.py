"""Mamba-2 SSD chunked scan (Pallas TPU).

Grid: (B*H, n_chunks) — chunks are the sequential innermost dimension;
the SSM state (P, N) persists in VMEM scratch across chunks (the
recurrent carry).  Per chunk, the intra-chunk quadratic form runs on the
MXU ((Q, N) x (N, Q) and (Q, Q) x (Q, P) matmuls) while the carried
state contributes through a (Q, N) x (N, P) matmul — this is the "state
space duality" (arXiv:2405.21060 §6) mapped to VMEM tiles.

Tiles per (bh, c) step:
  x  : (1, 1, Q, P)    dt: (1, 1, Q)
  Bm : (1, 1, Q, N)    Cm: (1, 1, Q, N)
  y  : (1, 1, Q, P)    state out: (1, P, N) (written at the last chunk)

VMEM working set with Q=128, P=64, N=128: ~0.4 MB — small; the kernel is
bandwidth-bound, which is why the perf follow-up fuses the gated norm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                state_scr, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (Q,)
    A = a_ref[0]                             # scalar (per bh head)
    Bm = b_ref[0, 0].astype(jnp.float32)     # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)     # (Q, N)

    dA = dt * A                              # (Q,) <= 0
    cum = jnp.cumsum(dA)                     # (Q,)
    # intra-chunk: L[q,s] = exp(cum[q]-cum[s]) for s<=q
    Lq = cum[:, None] - cum[None, :]
    Q = x.shape[0]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Lmat = jnp.where(si <= qi, jnp.exp(Lq), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    y_intra = jax.lax.dot(CB * Lmat, x * dt[:, None])           # (Q, P)
    # inter-chunk: y_inter = (C * exp(cum)) @ state^T   (state: (P, N))
    h = state_scr[...]
    y_inter = jax.lax.dot_general(Cm * jnp.exp(cum)[:, None], h,
                                  (((1,), (1,)), ((), ())))     # (Q, P)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(cum[-1]) h + sum_s decay_end[s] dt[s] x[s] B[s]^T
    decay_end = jnp.exp(cum[-1] - cum) * dt                     # (Q,)
    upd = jax.lax.dot_general(x, Bm * decay_end[:, None],
                              (((0,), (0,)), ((), ())))         # (P, N)
    state_scr[...] = h * jnp.exp(cum[-1]) + upd

    @pl.when(ci == nc - 1)
    def _fin():
        hout_ref[0] = state_scr[...].astype(hout_ref.dtype)


def ssd_scan_bh(x, dt, A, Bm, Cm, h0, *, chunk: int = 128,
                interpret: bool = False):
    """x: (BH, S, P); dt: (BH, S); A: (BH,); Bm/Cm: (BH, S, N);
    h0: (BH, P, N).  Returns (y (BH, S, P), h_final (BH, P, N))."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        # dt=0 for padding -> decay 1, no state contribution
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q
    xr = x.reshape(BH, nc, Q, P)
    dtr = dt.reshape(BH, nc, Q)
    br = Bm.reshape(BH, nc, Q, N)
    cr = Cm.reshape(BH, nc, Q, N)

    y, hout = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=Q),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1,), lambda bh, c: (bh,)),
            pl.BlockSpec((1, 1, Q, N), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, P, N), lambda bh, c: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, P, N), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, A, br, cr, h0)
    return y.reshape(BH, Sp, P)[:, :S], hout
