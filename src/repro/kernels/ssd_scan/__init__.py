from repro.kernels.ssd_scan import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
