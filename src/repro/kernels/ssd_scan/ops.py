"""Public wrapper: (B, S, H, ...) layout -> kernel (BH, ...) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bh


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(xh, dt, A, Bh, Ch, *, chunk: int = 128, init_state=None,
        interpret: bool | None = None):
    """Model-layer layout: xh (B,S,H,P), dt (B,S,H), A (H,), Bh/Ch (B,S,H,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)) — matches
    ``repro.models.ssm.ssd_chunked``.
    """
    if interpret is None:
        interpret = _on_cpu()
    B, S, H, P = xh.shape
    N = Bh.shape[-1]
    xb = xh.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtb = dt.transpose(0, 2, 1).reshape(B * H, S)
    Ab = jnp.tile(A, B)
    Bb = Bh.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Cb = Ch.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    h0 = (jnp.zeros((B * H, P, N), jnp.float32) if init_state is None
          else init_state.reshape(B * H, P, N))
    y, hf = ssd_scan_bh(xb, dtb, Ab, Bb, Cb, h0, chunk=chunk,
                        interpret=interpret)
    return (y.reshape(B, H, S, P).transpose(0, 2, 1, 3),
            hf.reshape(B, H, P, N))
