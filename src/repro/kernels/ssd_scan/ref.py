"""jnp oracle: naive sequential SSM recurrence (exact, O(S) steps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, h0=None):
    """x: (BH,S,P); dt: (BH,S); A: (BH,); Bm/Cm: (BH,S,N); h0: (BH,P,N).

    y[t] = C[t] · h[t],   h[t] = exp(dt[t] A) h[t-1] + dt[t] x[t] B[t]^T
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    h = jnp.zeros((BH, P, N), f32) if h0 is None else h0.astype(f32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dec = jnp.exp(dtt * A)[:, None, None]
        h = h * dec + jnp.einsum("bp,bn->bpn", xt * dtt[:, None], bt)
        y = jnp.einsum("bn,bpn->bp", ct, h)
        return h, y

    xs = (x.astype(f32).transpose(1, 0, 2), dt.astype(f32).T,
          Bm.astype(f32).transpose(1, 0, 2), Cm.astype(f32).transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h
