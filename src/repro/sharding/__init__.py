from repro.sharding.rules import (
    abstract_mesh,
    param_specs,
    opt_state_specs,
    batch_spec,
    cache_specs,
    fleet_specs,
    host_resident_bytes,
    named,
    data_axes_of,
)

__all__ = ["abstract_mesh", "param_specs", "opt_state_specs", "batch_spec",
           "cache_specs", "fleet_specs", "host_resident_bytes", "named",
           "data_axes_of"]
