"""Parameter / optimizer-state sharding rules.

The rules map parameter *paths* (and ranks) to PartitionSpecs over the
production mesh axes ("pod", "data", "model"):

* Megatron-style tensor parallelism on the "model" axis — attention heads
  and FFN hidden columns; expert-parallel MoE weights (leading expert dim
  on "model", matching the shard_map all-to-all dispatch).
* FSDP/ZeRO-style weight + optimizer sharding over the "data" axis — the
  first large replicated dim of each leaf is additionally sharded over
  "data" (and "pod" when present).  This is what keeps 671B-class configs
  within a v5e's HBM (see EXPERIMENTS.md §Dry-run).

Stacked layer params (leading scan "group" axes) are handled generically:
rules match the *trailing* dims, leading axes are padded with None.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.pytree import path_str


def abstract_mesh(axis_sizes: Tuple[int, ...], axis_names: Tuple[str, ...]):
    """Version-compatible ``jax.sharding.AbstractMesh`` constructor.

    JAX <= 0.4.x takes a single tuple of (name, size) pairs; newer
    releases take (axis_sizes, axis_names) positionally.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))

# (path regex, trailing-dims spec) — first match wins.
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"(^|/)embed$",                     (None, "model")),
    (r"(^|/)lm_head$",                   (None, "model")),
    # attention
    (r"(x?attn)/w[qkv]$",                (None, "model")),
    (r"(x?attn)/wo$",                    ("model", None)),
    # MLA
    (r"wq_a$",                           (None, None)),
    (r"wq_b$",                           (None, "model")),
    (r"wkv_a$",                          (None, None)),
    (r"w[kv]_b$",                        ("model", None, None)),
    # MoE (expert-parallel: expert dim on "model")
    (r"moe/router$",                     (None, None)),
    (r"moe/wi_gate$|moe/wi_up$|moe/wo$", ("model", None, None)),
    # dense MLPs (incl. shared experts)
    (r"wi_gate$|wi_up$|wi$",             (None, "model")),
    (r"(mlp|shared)/wo$",                ("model", None)),
    # SSM
    (r"in_proj$",                        (None, "model")),
    (r"out_proj$",                       ("model", None)),
    (r"conv_w$",                         (None, "model")),
    (r"conv_b$",                         ("model",)),
    (r"A_log$|/D$|dt_bias$",             (None,)),
    # MTP glue
    (r"mtp/proj$",                       (None, None)),
)


def data_axes_of(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _trailing_spec(path: str, leaf) -> Tuple:
    for pat, spec in _RULES:
        if re.search(pat, path):
            return spec
    return (None,) * leaf.ndim  # norms, scalars, biases: replicate


def _full_spec(path: str, leaf, mesh: Mesh, *, fsdp: bool,
               ep_all: bool = False) -> P:
    trailing = _trailing_spec(path, leaf)
    trailing = trailing[-leaf.ndim:] if leaf.ndim else ()
    spec = [None] * (leaf.ndim - len(trailing)) + list(trailing)
    # serving layout: shard the expert dim over the WHOLE mesh so expert
    # weights never move at decode time (1 expert per device on 16x16)
    if ep_all and re.search(r"moe/(wi_gate|wi_up|wo)$", path):
        all_axes = tuple(mesh.axis_names)
        n_all = mesh.size
        e_dim = leaf.ndim - 3
        if leaf.shape[e_dim] % n_all == 0:
            spec = [None] * leaf.ndim
            spec[e_dim] = all_axes
            return P(*spec)
    # pjit in_shardings require exact divisibility: drop non-dividing
    # assignments and re-place "model" on another dim when possible
    # (e.g. Qwen's 60 experts on a 16-way axis -> shard d_ff instead).
    model = mesh.shape.get("model", 1)
    dropped_model = False
    for i, s in enumerate(spec):
        if s == "model" and leaf.shape[i] % model != 0:
            spec[i] = None
            dropped_model = True
    if dropped_model:
        for i in reversed(range(leaf.ndim)):
            if spec[i] is None and leaf.shape[i] % model == 0 \
               and leaf.shape[i] >= model:
                spec[i] = "model"
                break
    if fsdp and leaf.ndim >= 2:
        daxes = data_axes_of(mesh)
        n_data = 1
        for a in daxes:
            n_data *= mesh.shape[a]
        if n_data > 1:
            for i, s in enumerate(spec):
                if s is None and leaf.shape[i] % n_data == 0 and leaf.shape[i] >= n_data:
                    spec[i] = daxes if len(daxes) > 1 else daxes[0]
                    break
    return P(*spec)


def param_specs(params, mesh: Mesh, *, fsdp: bool = True,
                ep_all: bool = False):
    """PartitionSpec pytree matching ``params``.

    ``ep_all``: serving layout — MoE expert dims shard over every mesh
    axis (used with the ``replicated_ep`` decode path)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_full_spec(path_str(p), leaf, mesh, fsdp=fsdp, ep_all=ep_all)
             for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(params, mesh: Mesh, *, fsdp: bool = True,
                    state=None):
    """Specs for AdamW state {m, v, step}: moments follow the params.

    Pass the actual ``state`` to cover quantized moment policies — an
    int8-v state carries a ``"v_scale"`` tree of scalar per-tensor
    scales, which replicate."""
    ps = param_specs(params, mesh, fsdp=fsdp)
    specs = {"m": ps, "v": ps, "step": P()}
    if state is not None and "v_scale" in state:
        specs["v_scale"] = jax.tree.map(lambda _: P(), state["v_scale"])
    return specs


def fleet_specs(tree, mesh: Mesh):
    """Stacked-fleet layout over a ``("hosts",)`` mesh.

    The fleet drivers stack per-device params / optimizer state / batch
    streams along a leading device axis (``federated.device.train_fleet``);
    that axis shards over "hosts" when divisible — each host owns a
    contiguous run of simulated devices — and everything else (per-lane
    scalars that stacked into non-divisible vectors, e.g. a padded
    remainder) replicates.  Non-divisible dims replicate, never error.
    """
    n = mesh.shape["hosts"]

    def spec(leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd >= 1 and n > 1 and leaf.shape[0] % n == 0:
            return P(*(["hosts"] + [None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree.map(spec, tree)


def host_resident_bytes(tree, device_index: int = 0) -> int:
    """Bytes of ``tree`` resident on ONE device of the fleet mesh.

    For a ``fleet_specs``-sharded state this is ``total / n_hosts`` plus
    any replicated leaves — the per-host footprint that bounds how many
    simulated devices a host can keep resident between rounds."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        for sh in leaf.addressable_shards:
            if sh.device.id == device_index:
                total += int(sh.data.size) * sh.data.dtype.itemsize
    return total


def batch_spec(batch, mesh: Mesh):
    """Shard every batch array's leading (batch) dim over the data axes."""
    daxes = data_axes_of(mesh)
    ax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]

    def spec(x):
        if x.ndim == 0 or x.shape[0] % n_data != 0:
            return P(*([None] * x.ndim))  # tiny decode batches replicate
        return P(*([ax] + [None] * (x.ndim - 1)))

    return jax.tree.map(spec, batch)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def cache_specs(cache, mesh: Mesh, *, batch: int, seq: int):
    """Decode-cache sharding.

    Heuristic per leaf: shard the batch-sized dim over the data axes when
    divisible; then shard the cache-sequence dim over "model" (or over
    *all* axes when the batch is too small to shard — the long_500k
    sequence-parallel decode layout).  Head-sized dims stay replicated
    (they are often non-divisible GQA KV head counts; XLA pads).
    """
    daxes = data_axes_of(mesh)
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]
    model = mesh.shape.get("model", 1)
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    all_axes = tuple(list(daxes) + ["model"])

    def spec(leaf):
        s = [None] * leaf.ndim
        batch_done = False
        for i, d in enumerate(leaf.shape):
            if d == batch and batch % n_data == 0 and n_data > 1:
                s[i] = dax
                batch_done = True
                break
        for i, d in enumerate(leaf.shape):
            if s[i] is None and d == seq and seq > 1:
                if batch_done and d % model == 0:
                    s[i] = "model"
                elif not batch_done and d % (n_data * model) == 0:
                    s[i] = all_axes
                break
        return P(*s)

    return jax.tree.map(spec, cache)


def paged_cache_specs(cache, mesh: Mesh, *, batch_axes, seq_axes):
    """Paged-cache sharding: block pools + slot-resident leaves.

    ``batch_axes`` / ``seq_axes`` are the per-leaf axis trees from
    ``models.model.decode_cache_batch_axes`` / ``decode_cache_seq_axes``
    (the paged layout keeps the contiguous layout's axis positions: the
    batch axis holds ``n_blocks`` for pool leaves, ``n_slots`` for
    slot-resident ones).

    Pool leaves (seq axis >= 0): the ``n_blocks`` dim shards over the
    data axes — each device owns a CONTIGUOUS run of block ids, which is
    exactly the split ``serve.paged.PagedAllocator``'s per-shard free
    lists track — and the trailing feature dim shards over "model" when
    divisible (KV heads x head_dim, MLA latent width).  Slot-resident
    leaves (seq axis < 0: ssm/hybrid state, encdec cross KV + memory)
    shard their ``n_slots`` dim over the data axes like the contiguous
    cache.  Non-divisible dims replicate — never an error.
    """
    daxes = data_axes_of(mesh)
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]
    model = mesh.shape.get("model", 1)
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def spec(leaf, bax, sax):
        s = [None] * leaf.ndim
        if n_data > 1 and leaf.shape[bax] % n_data == 0:
            s[bax] = dax
        if sax >= 0 and model > 1:
            last = leaf.ndim - 1
            if last != bax and s[last] is None \
               and leaf.shape[last] % model == 0 and leaf.shape[last] >= model:
                s[last] = "model"
        return P(*s)

    return jax.tree.map(spec, cache, batch_axes, seq_axes)
