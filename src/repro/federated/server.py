"""DeepFusion central server (paper Fig. 3): the three-phase pipeline.

Phase I   — local knowledge clustering: cluster uploaded on-device LLMs
            by data embeddings into K domains, weight-average per cluster
            into proxy models m̄_i (§IV.B).
Phase II  — cross-architecture KD: distill each proxy into a dense "MoE
            base model" M_i with the VAA module (§IV.C, Eq. 7-11) on
            public server data.
Phase III — merge the K base models into the global MoE (Fig. 6) and
            tune with frozen experts (§IV.D).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering, distill, merge, proxy, tuning
from repro.core import vaa as vaa_mod
from repro.data.federated import FederatedCorpus
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.utils.pytree import tree_size


@dataclasses.dataclass
class ServerConfig:
    moe_cfg: ModelConfig
    distill_steps: int = 60
    distill_batch: int = 8
    distill_lr: float = 1e-3
    tune_steps: int = 60
    tune_batch: int = 8
    tune_lr: float = 5e-4
    seq_len: int = 64
    alpha: float = 1.0            # L_FM weight (Eq. 11)
    beta: float = 1.0             # L_KL weight (Eq. 11)
    temperature: float = 2.0
    n_stages: int = 4             # J representation stages
    vaa_dim: int = 128
    vaa_heads: int = 4
    p_q: int = 64                 # total VAA queries
    seed: int = 0
    # AdamW moment storage for Phase II distillation ('' | 'bf16' |
    # 'int8', see repro.optim.adamw.resolve_moment_policy); the compiled
    # epoch retraces per state structure, no key change needed
    state_policy: str = ""


@functools.lru_cache(maxsize=64)
def _distill_epoch_fn(base_cfg, t_cfg, alpha, beta, temperature, n_stages,
                      vaa_heads, p_q, steps, lr, warmup, mesh):
    """One compiled scan-epoch per (student, teacher, hparams) combo —
    proxies sharing a teacher family, and baseline re-runs (FedKMT/OFA),
    reuse it instead of re-jitting.  Trainable/opt buffers are donated;
    the whole Phase II epoch is one XLA program (docs/loops.md)."""
    return jax.jit(distill.make_distill_epoch(
        base_cfg, t_cfg, steps=steps,
        schedule=cosine_schedule(lr, steps, warmup=warmup),
        alpha=alpha, beta=beta, temperature=temperature,
        n_stages=n_stages, vaa_heads=vaa_heads, p_q=p_q,
        optimizer_update=adamw_update, mesh=mesh), donate_argnums=(0, 1))


_TUNE_EPOCH_CACHE: Dict = {}


def _tune_epoch_fn(moe_cfg, mesh, mask, steps, lr, warmup):
    # mask leaves are plain bools, so they can join the key directly
    key = (moe_cfg, mesh, tuple(jax.tree.leaves(mask)), steps, lr, warmup)
    if key not in _TUNE_EPOCH_CACHE:
        if len(_TUNE_EPOCH_CACHE) > 64:
            _TUNE_EPOCH_CACHE.clear()
        _TUNE_EPOCH_CACHE[key] = jax.jit(
            tuning.make_tune_epoch(
                moe_cfg, mask, steps=steps,
                schedule=cosine_schedule(lr, steps, warmup=warmup),
                mesh=mesh), donate_argnums=(0, 1))
    return _TUNE_EPOCH_CACHE[key]


class DeepFusionServer:
    def __init__(self, cfg: ServerConfig, corpus: FederatedCorpus,
                 device_cfgs: Sequence[ModelConfig], *, mesh=None,
                 log: Callable[[str], None] = lambda s: None):
        self.cfg = cfg
        self.corpus = corpus
        self.device_cfgs = list(device_cfgs)
        self.mesh = mesh
        self.log = log
        self.report: Dict = {}

    # ------------------------------------------------------------------
    # Phase I
    # ------------------------------------------------------------------
    def cluster(self, uploads: Sequence[Dict]):
        K = self.cfg.moe_cfg.n_experts
        emb = np.stack([u["embedding"] for u in uploads])
        arch_ids = [u["arch_id"] for u in uploads]
        result = clustering.cluster_devices(emb, K, arch_ids=arch_ids,
                                            seed=self.cfg.seed)
        proxies = proxy.build_proxies([u["params"] for u in uploads], result,
                                      arch_ids)
        self.report["n_clusters"] = len(proxies)
        self.report["cluster_sizes"] = [len(p["members"]) for p in proxies]
        self.log(f"Phase I: {len(uploads)} uploads -> {len(proxies)} proxies "
                 f"{self.report['cluster_sizes']}")
        return proxies, result

    # ------------------------------------------------------------------
    # Phase II
    # ------------------------------------------------------------------
    def distill_proxy(self, proxy_item: Dict, base_cfg: ModelConfig,
                      *, init_params=None, seed_offset: int = 0):
        """Distill one proxy (teacher) into one MoE base model (student)."""
        scfg = self.cfg
        t_cfg = self.device_cfgs[proxy_item["arch"]]
        t_params = proxy_item["params"]
        key = jax.random.PRNGKey(scfg.seed + 101 + seed_offset)
        # copy caller-provided warm starts: the compiled epoch donates its
        # trainable buffers, and donation must never eat a caller's arrays
        s_params = jax.tree.map(jnp.array, init_params) \
            if init_params is not None else M.init_params(key, base_cfg)
        vaa_params = vaa_mod.init_vaa(
            jax.random.PRNGKey(scfg.seed + 202 + seed_offset),
            n_stages=scfg.n_stages, d_student=base_cfg.d_model,
            d_teacher=t_cfg.d_model, d=scfg.vaa_dim, n_heads=scfg.vaa_heads,
            p_q=scfg.p_q)
        trainable = {"student": s_params, "vaa": vaa_params}
        opt = adamw_init(trainable, policy=scfg.state_policy)
        epoch = _distill_epoch_fn(base_cfg, t_cfg, scfg.alpha, scfg.beta,
                                  scfg.temperature, scfg.n_stages,
                                  scfg.vaa_heads, scfg.p_q,
                                  scfg.distill_steps, scfg.distill_lr,
                                  max(scfg.distill_steps // 20, 1), self.mesh)
        batches = self.corpus.mixed_eval_batches(scfg.distill_steps,
                                                 scfg.distill_batch,
                                                 scfg.seq_len)
        trainable, opt, losses = epoch(trainable, opt, t_params, batches)
        hist = [float(x) for x in np.asarray(losses)]
        self.log(f"Phase II: proxy c{proxy_item['cluster']} distilled "
                 f"loss {hist[0]:.3f}->{hist[-1]:.3f}")
        return trainable["student"], hist

    # ------------------------------------------------------------------
    # Phase III
    # ------------------------------------------------------------------
    def merge_and_tune(self, base_params_list: List):
        scfg = self.cfg
        key = jax.random.PRNGKey(scfg.seed + 303)
        moe_params = merge.merge_into_moe(key, scfg.moe_cfg, base_params_list)
        mask, opt = tuning.init_tuning(moe_params)
        self.report["trainable_fraction"] = tuning.trainable_fraction(moe_params)
        self.log(f"Phase III: trainable fraction "
                 f"{self.report['trainable_fraction']:.3f}")
        epoch = _tune_epoch_fn(scfg.moe_cfg, self.mesh, mask, scfg.tune_steps,
                               scfg.tune_lr, max(scfg.tune_steps // 20, 1))
        batches = self.corpus.mixed_eval_batches(scfg.tune_steps,
                                                 scfg.tune_batch,
                                                 scfg.seq_len,
                                                 seed_salt0=10_000)
        moe_params, opt, losses = epoch(moe_params, opt, batches)
        hist = [float(x) for x in np.asarray(losses)]
        self.log(f"Phase III: tune loss {hist[0]:.3f}->{hist[-1]:.3f}")
        return moe_params, hist

    # ------------------------------------------------------------------
    def run(self, uploads: Sequence[Dict]):
        """Full pipeline.  Returns (moe_params, report)."""
        t0 = time.time()
        proxies, _ = self.cluster(uploads)
        base_cfg = merge.base_config_of(self.cfg.moe_cfg)
        bases, distill_hists = [], []
        for i, p in enumerate(proxies):
            s_params, hist = self.distill_proxy(p, base_cfg, seed_offset=i)
            bases.append(s_params)
            distill_hists.append(hist)
        moe_params, tune_hist = self.merge_and_tune(bases)
        self.report["distill_hists"] = distill_hists
        self.report["tune_hist"] = tune_hist
        self.report["comm_bytes"] = int(sum(u["upload_bytes"] for u in uploads))
        self.report["wall_s"] = time.time() - t0
        return moe_params, self.report
