"""DeepFusion central server (paper Fig. 3): the three-phase pipeline.

Phase I   — local knowledge clustering: cluster uploaded on-device LLMs
            by data embeddings into K domains, weight-average per cluster
            into proxy models m̄_i (§IV.B).
Phase II  — cross-architecture KD: distill each proxy into a dense "MoE
            base model" M_i with the VAA module (§IV.C, Eq. 7-11) on
            public server data.
Phase III — merge the K base models into the global MoE (Fig. 6) and
            tune with frozen experts (§IV.D).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering, distill, merge, proxy, tuning
from repro.core import vaa as vaa_mod
from repro.data.federated import FederatedCorpus
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.utils.pytree import tree_average, tree_size


@dataclasses.dataclass(frozen=True)
class AsyncFleetConfig:
    """Participation schedule for async / hierarchical fleet rounds.

    Per round a sampled subset of the fleet reports its local update;
    the server merges deliverable reports with FedAsync-style
    staleness-discounted weights ``alpha / (1 + staleness)^
    staleness_power`` (``staleness_weight``).  Reports later than
    ``deadline_s`` are handled by ``deadline_policy``:

      * ``"drop"``    — the late update is discarded;
      * ``"stale"``   — it is carried and merged in a later round with
                        its accrued staleness discount;
      * ``"standby"`` — the round over-selects ``over_select`` extra
                        standby devices so the on-time quorum still
                        meets the participation target; late reports
                        are dropped.

    ``hierarchical`` interposes one sub-server per arch bucket: devices
    report edge-locally and only each bucket's merged aggregate crosses
    the global link (comm accounting bills the two tiers separately —
    the merge math is identical to flat mode by construction).
    """
    rounds: int = 3
    steps_per_round: int = 10
    participation: float = 1.0     # fraction of the fleet sampled per round
    alpha: float = 0.6             # FedAsync base mixing weight
    staleness_power: float = 0.5   # a in alpha / (1 + staleness)^a
    deadline_s: float = float("inf")
    deadline_policy: str = "stale"  # "drop" | "stale" | "standby"
    over_select: float = 0.25      # standby headroom (deadline_policy=standby)
    server_momentum: float = 0.0   # G <- mom*G + (1-mom)*round_average
    hierarchical: bool = False     # per-arch-bucket sub-servers (edge tier)
    seed: int = 0

    def validate(self) -> "AsyncFleetConfig":
        if self.deadline_policy not in ("drop", "stale", "standby"):
            raise ValueError(
                f"deadline_policy {self.deadline_policy!r} not in "
                "('drop', 'stale', 'standby')")
        if not (0.0 < self.participation <= 1.0):
            raise ValueError("participation must be in (0, 1]")
        if self.rounds < 1 or self.steps_per_round < 1:
            raise ValueError("rounds and steps_per_round must be >= 1")
        return self


def staleness_weight(alpha: float, staleness: float, power: float) -> float:
    """FedAsync mixing weight for a report ``staleness`` rounds old."""
    return float(alpha) / (1.0 + float(staleness)) ** float(power)


class FleetAggregator:
    """Staleness-discounted per-arch-bucket merging (FedAsync-style).

    Each round's deliverable reports for a bucket are combined into a
    weighted average (weights ``staleness_weight(alpha, tau, power)``)
    and mixed into the bucket's running aggregate under
    ``server_momentum``.  All-fresh reports get equal weights, which is
    computed as the *plain* ``tree_average`` — so with full on-time
    participation one round reproduces the synchronous FedAvg merge
    bit-for-bit (tests/test_fleet_async.py property tests).
    """

    def __init__(self, acfg: AsyncFleetConfig):
        self.acfg = acfg
        self.aggregates: Dict = {}       # bucket key -> merged params
        self.merged_staleness: List[int] = []

    def merge_round(self, bucket_key, reports: Sequence[Dict]):
        """``reports``: [{"device_id", "params", "staleness"}] — merged
        in device-id order so float accumulation is deterministic."""
        if not reports:
            return self.aggregates.get(bucket_key)
        reports = sorted(reports, key=lambda r: r["device_id"])
        ws = [staleness_weight(self.acfg.alpha, r["staleness"],
                               self.acfg.staleness_power) for r in reports]
        self.merged_staleness.extend(int(r["staleness"]) for r in reports)
        if len(set(ws)) == 1:
            # uniform weights ARE the plain average — short-circuiting
            # keeps the all-fresh round bitwise equal to FedAvg
            avg = tree_average([r["params"] for r in reports])
        else:
            total = sum(ws)
            wn = [w / total for w in ws]
            avg = jax.tree.map(
                lambda *xs: sum(w * x.astype(jnp.float32)
                                for w, x in zip(wn, xs)).astype(xs[0].dtype),
                *[r["params"] for r in reports])
        prev = self.aggregates.get(bucket_key)
        mom = self.acfg.server_momentum
        if prev is not None and mom > 0.0:
            avg = jax.tree.map(
                lambda g, a: (mom * g.astype(jnp.float32) +
                              (1.0 - mom) * a.astype(jnp.float32)
                              ).astype(a.dtype), prev, avg)
        self.aggregates[bucket_key] = avg
        return avg

    def staleness_histogram(self) -> Dict[int, int]:
        hist: Dict[int, int] = {}
        for t in self.merged_staleness:
            hist[t] = hist.get(t, 0) + 1
        return hist


@dataclasses.dataclass
class ServerConfig:
    moe_cfg: ModelConfig
    distill_steps: int = 60
    distill_batch: int = 8
    distill_lr: float = 1e-3
    tune_steps: int = 60
    tune_batch: int = 8
    tune_lr: float = 5e-4
    seq_len: int = 64
    alpha: float = 1.0            # L_FM weight (Eq. 11)
    beta: float = 1.0             # L_KL weight (Eq. 11)
    temperature: float = 2.0
    n_stages: int = 4             # J representation stages
    vaa_dim: int = 128
    vaa_heads: int = 4
    p_q: int = 64                 # total VAA queries
    seed: int = 0
    # AdamW moment storage for Phase II distillation ('' | 'bf16' |
    # 'int8', see repro.optim.adamw.resolve_moment_policy); the compiled
    # epoch retraces per state structure, no key change needed
    state_policy: str = ""
    # async fleet participation schedule; None keeps the synchronous
    # one-shot `train_fleet` path (see AsyncFleetConfig)
    schedule: Optional[AsyncFleetConfig] = None


@functools.lru_cache(maxsize=64)
def _distill_epoch_fn(base_cfg, t_cfg, alpha, beta, temperature, n_stages,
                      vaa_heads, p_q, steps, lr, warmup, mesh):
    """One compiled scan-epoch per (student, teacher, hparams) combo —
    proxies sharing a teacher family, and baseline re-runs (FedKMT/OFA),
    reuse it instead of re-jitting.  Trainable/opt buffers are donated;
    the whole Phase II epoch is one XLA program (docs/loops.md)."""
    return jax.jit(distill.make_distill_epoch(
        base_cfg, t_cfg, steps=steps,
        schedule=cosine_schedule(lr, steps, warmup=warmup),
        alpha=alpha, beta=beta, temperature=temperature,
        n_stages=n_stages, vaa_heads=vaa_heads, p_q=p_q,
        optimizer_update=adamw_update, mesh=mesh), donate_argnums=(0, 1))


_TUNE_EPOCH_CACHE: Dict = {}


def _tune_epoch_fn(moe_cfg, mesh, mask, steps, lr, warmup):
    # mask leaves are plain bools, so they can join the key directly
    key = (moe_cfg, mesh, tuple(jax.tree.leaves(mask)), steps, lr, warmup)
    if key not in _TUNE_EPOCH_CACHE:
        if len(_TUNE_EPOCH_CACHE) > 64:
            _TUNE_EPOCH_CACHE.clear()
        _TUNE_EPOCH_CACHE[key] = jax.jit(
            tuning.make_tune_epoch(
                moe_cfg, mask, steps=steps,
                schedule=cosine_schedule(lr, steps, warmup=warmup),
                mesh=mesh), donate_argnums=(0, 1))
    return _TUNE_EPOCH_CACHE[key]


class DeepFusionServer:
    def __init__(self, cfg: ServerConfig, corpus: FederatedCorpus,
                 device_cfgs: Sequence[ModelConfig], *, mesh=None,
                 log: Callable[[str], None] = lambda s: None):
        self.cfg = cfg
        self.corpus = corpus
        self.device_cfgs = list(device_cfgs)
        self.mesh = mesh
        self.log = log
        self.report: Dict = {}

    # ------------------------------------------------------------------
    # Phase I
    # ------------------------------------------------------------------
    def cluster(self, uploads: Sequence[Dict]):
        K = self.cfg.moe_cfg.n_experts
        emb = np.stack([u["embedding"] for u in uploads])
        arch_ids = [u["arch_id"] for u in uploads]
        result = clustering.cluster_devices(emb, K, arch_ids=arch_ids,
                                            seed=self.cfg.seed)
        proxies = proxy.build_proxies([u["params"] for u in uploads], result,
                                      arch_ids)
        self.report["n_clusters"] = len(proxies)
        self.report["cluster_sizes"] = [len(p["members"]) for p in proxies]
        self.log(f"Phase I: {len(uploads)} uploads -> {len(proxies)} proxies "
                 f"{self.report['cluster_sizes']}")
        return proxies, result

    # ------------------------------------------------------------------
    # Phase II
    # ------------------------------------------------------------------
    def distill_proxy(self, proxy_item: Dict, base_cfg: ModelConfig,
                      *, init_params=None, seed_offset: int = 0):
        """Distill one proxy (teacher) into one MoE base model (student)."""
        scfg = self.cfg
        t_cfg = self.device_cfgs[proxy_item["arch"]]
        t_params = proxy_item["params"]
        key = jax.random.PRNGKey(scfg.seed + 101 + seed_offset)
        # copy caller-provided warm starts: the compiled epoch donates its
        # trainable buffers, and donation must never eat a caller's arrays
        s_params = jax.tree.map(jnp.array, init_params) \
            if init_params is not None else M.init_params(key, base_cfg)
        vaa_params = vaa_mod.init_vaa(
            jax.random.PRNGKey(scfg.seed + 202 + seed_offset),
            n_stages=scfg.n_stages, d_student=base_cfg.d_model,
            d_teacher=t_cfg.d_model, d=scfg.vaa_dim, n_heads=scfg.vaa_heads,
            p_q=scfg.p_q)
        trainable = {"student": s_params, "vaa": vaa_params}
        opt = adamw_init(trainable, policy=scfg.state_policy)
        epoch = _distill_epoch_fn(base_cfg, t_cfg, scfg.alpha, scfg.beta,
                                  scfg.temperature, scfg.n_stages,
                                  scfg.vaa_heads, scfg.p_q,
                                  scfg.distill_steps, scfg.distill_lr,
                                  max(scfg.distill_steps // 20, 1), self.mesh)
        batches = self.corpus.mixed_eval_batches(scfg.distill_steps,
                                                 scfg.distill_batch,
                                                 scfg.seq_len)
        trainable, opt, losses = epoch(trainable, opt, t_params, batches)
        hist = [float(x) for x in np.asarray(losses)]
        self.log(f"Phase II: proxy c{proxy_item['cluster']} distilled "
                 f"loss {hist[0]:.3f}->{hist[-1]:.3f}")
        return trainable["student"], hist

    # ------------------------------------------------------------------
    # Phase III
    # ------------------------------------------------------------------
    def merge_and_tune(self, base_params_list: List):
        scfg = self.cfg
        key = jax.random.PRNGKey(scfg.seed + 303)
        moe_params = merge.merge_into_moe(key, scfg.moe_cfg, base_params_list)
        mask, opt = tuning.init_tuning(moe_params)
        self.report["trainable_fraction"] = tuning.trainable_fraction(moe_params)
        self.log(f"Phase III: trainable fraction "
                 f"{self.report['trainable_fraction']:.3f}")
        epoch = _tune_epoch_fn(scfg.moe_cfg, self.mesh, mask, scfg.tune_steps,
                               scfg.tune_lr, max(scfg.tune_steps // 20, 1))
        batches = self.corpus.mixed_eval_batches(scfg.tune_steps,
                                                 scfg.tune_batch,
                                                 scfg.seq_len,
                                                 seed_salt0=10_000)
        moe_params, opt, losses = epoch(moe_params, opt, batches)
        hist = [float(x) for x in np.asarray(losses)]
        self.log(f"Phase III: tune loss {hist[0]:.3f}->{hist[-1]:.3f}")
        return moe_params, hist

    # ------------------------------------------------------------------
    def run(self, uploads: Sequence[Dict]):
        """Full pipeline.  Returns (moe_params, report)."""
        t0 = time.time()
        proxies, _ = self.cluster(uploads)
        base_cfg = merge.base_config_of(self.cfg.moe_cfg)
        bases, distill_hists = [], []
        for i, p in enumerate(proxies):
            s_params, hist = self.distill_proxy(p, base_cfg, seed_offset=i)
            bases.append(s_params)
            distill_hists.append(hist)
        moe_params, tune_hist = self.merge_and_tune(bases)
        self.report["distill_hists"] = distill_hists
        self.report["tune_hist"] = tune_hist
        self.report["comm_bytes"] = int(sum(u["upload_bytes"] for u in uploads))
        self.report["wall_s"] = time.time() - t0
        return moe_params, self.report
