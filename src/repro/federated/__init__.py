from repro.federated.device import DeviceSpec, train_device, device_upload_bytes
from repro.federated.server import DeepFusionServer, ServerConfig
from repro.federated.simulation import SimulationConfig, run_deepfusion

__all__ = ["DeviceSpec", "train_device", "device_upload_bytes",
           "DeepFusionServer", "ServerConfig",
           "SimulationConfig", "run_deepfusion"]
