from repro.federated.async_fleet import train_fleet_async
from repro.federated.device import (STRAGGLER_PROFILES, DeviceSpec,
                                    TrafficModel, device_upload_bytes,
                                    sample_traffic, train_device, train_fleet)
from repro.federated.server import (AsyncFleetConfig, DeepFusionServer,
                                    FleetAggregator, ServerConfig,
                                    staleness_weight)
from repro.federated.simulation import (SimulationConfig, build_fleet,
                                        run_deepfusion)

__all__ = ["DeviceSpec", "TrafficModel", "STRAGGLER_PROFILES",
           "sample_traffic", "train_device", "train_fleet",
           "train_fleet_async", "device_upload_bytes", "DeepFusionServer",
           "ServerConfig", "AsyncFleetConfig", "FleetAggregator",
           "staleness_weight", "SimulationConfig", "build_fleet",
           "run_deepfusion"]
