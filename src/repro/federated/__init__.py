from repro.federated.device import (DeviceSpec, device_upload_bytes,
                                    train_device, train_fleet)
from repro.federated.server import DeepFusionServer, ServerConfig
from repro.federated.simulation import SimulationConfig, run_deepfusion

__all__ = ["DeviceSpec", "train_device", "train_fleet",
           "device_upload_bytes", "DeepFusionServer", "ServerConfig",
           "SimulationConfig", "run_deepfusion"]
