"""Async / hierarchical fleet rounds with straggler + dropout dynamics.

`train_fleet` trains every device's whole local run as one synchronous
pass; real edge fleets don't work like that — devices go offline, report
late, and the server cannot wait for the slowest phone on the planet.
This driver simulates the paper's deployment story at fleet scale:

* **Rounds.**  Local training is cut into ``rounds`` rounds of
  ``steps_per_round`` steps.  Devices keep their OWN params between
  rounds (DeepFusion is one-shot FL — there is no global pull-down), so
  with every device online in every round the final per-device params
  are bit-identical to a single `train_fleet` run of the same total
  steps: the per-round scan computes exactly steps ``[r*k, (r+1)*k)`` of
  the same schedule over the same batch stream.

* **Participation + stragglers.**  Each round a seeded subset of the
  fleet is selected to report (``AsyncFleetConfig.participation``);
  every online device trains, but only delivered reports reach the
  server.  ``DeviceSpec.traffic`` (dropout, lognormal latency,
  availability windows) decides who is online and who misses the
  ``deadline_s`` — late reports follow ``deadline_policy`` (drop /
  carry-as-stale / standby over-selection).  All draws are pure
  functions of ``(seed, device, round)``, so runs replay bit-identically
  and a dropped device's batch stream continues exactly where it paused.

* **Merging.**  Delivered reports merge per arch bucket through
  ``server.FleetAggregator`` with FedAsync staleness discounts
  ``alpha / (1 + staleness)^a``.  ``hierarchical=True`` routes device
  reports to per-bucket sub-servers and ships only each bucket's
  aggregate across the global link — same merge math, cheaper WAN.

* **Comm accounting** bills only devices that actually delivered a
  report that round (`device_upload_bytes` of the *configured* model,
  Fig. 8 style); hierarchical mode splits edge-tier vs global-tier
  bytes.

* **Multi-host.**  ``n_hosts > 1`` shards every bucket's stacked device
  axis over a ``("hosts",)`` mesh (``sharding.rules.fleet_specs``), so
  the resident fleet state per host — and with it the fleet size one
  simulation sustains — scales linearly with hosts.

Compilation: one executable per (bucket cfg, bucket size) for the whole
run — offline devices are masked inside the vmapped round program, not
sliced out of it, so the participant set never changes the shapes.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedCorpus
from repro.federated.device import (DeviceSpec, _device_init,
                                    _fleet_round_fn, _pad_lanes,
                                    _shard_bucket, _stack_trees, _upload,
                                    device_upload_bytes, fleet_buckets,
                                    model_param_bytes, sample_traffic)
from repro.federated.server import AsyncFleetConfig, FleetAggregator


def _zeros_like_batches(batches):
    return jax.tree.map(lambda x: np.zeros_like(np.asarray(x)), batches)


def train_fleet_async(fleet: Sequence[DeviceSpec], corpus: FederatedCorpus,
                      acfg: AsyncFleetConfig, *, batch: int, seq_len: int,
                      lr: float = 3e-3, seed: int = 0,
                      state_policy: str = "", n_hosts: int = 1, mesh=None,
                      log: Callable[[str], None] = lambda s: None
                      ) -> Tuple[List[Dict], Dict]:
    """Returns ``(uploads, fleet_report)``.

    ``uploads`` matches `train_fleet`'s contract (fleet order, same
    ``_upload`` payloads — a device's ``losses`` only cover the rounds
    it actually trained).  ``fleet_report`` carries the per-round
    simulation log: participation, staleness histogram, effective comm
    bytes, and the per-bucket staleness-merged aggregates.
    """
    acfg.validate()
    if mesh is None and n_hosts > 1:
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(n_hosts)
    n_shards = mesh.shape["hosts"] if mesh is not None else 1

    k = acfg.steps_per_round
    total_steps = acfg.rounds * k
    warmup = max(total_steps // 20, 1)
    n_fleet = len(fleet)
    by_id = {s.device_id: s for s in fleet}

    buckets = fleet_buckets(fleet)
    state: Dict = {}
    for cfg, specs in buckets.items():
        inits = [_device_init(s, seed, state_policy) for s in specs]
        state[cfg] = {
            "specs": specs,
            "params": _stack_trees([p for p, _ in inits]),
            "opt": _stack_trees([o for _, o in inits]),
        }
    local_step = {s.device_id: 0 for s in fleet}
    losses: Dict[int, List[float]] = {s.device_id: [] for s in fleet}

    aggregator = FleetAggregator(acfg)
    pending: List[Dict] = []     # late reports carried across rounds
    rounds_log: List[Dict] = []
    comm_global = 0
    comm_edge = 0
    lost_reports = 0

    for r in range(acfg.rounds):
        traffic = {s.device_id: sample_traffic(s, r, acfg.seed)
                   for s in fleet}
        online = {d: t[1] for d, t in traffic.items()}

        # -- participation sampling (seeded, fleet-order independent) --
        target = max(1, math.ceil(acfg.participation * n_fleet))
        n_sel = target
        if acfg.deadline_policy == "standby":
            n_sel = min(n_fleet, math.ceil(target * (1 + acfg.over_select)))
        if n_sel >= n_fleet:
            selected = {s.device_id for s in fleet}
        else:
            rng = np.random.default_rng((acfg.seed, 424_242, r))
            ids = sorted(by_id)
            selected = set(np.asarray(ids)[
                rng.choice(n_fleet, size=n_sel, replace=False)].tolist())

        # -- every online device trains its round (one program/bucket) --
        for cfg, st in state.items():
            specs = st["specs"]
            active = np.array([online[s.device_id] for s in specs])
            per_dev = [
                corpus.device_batches(s.device_id, k, batch, seq_len,
                                      start=local_step[s.device_id])
                if online[s.device_id] else None for s in specs]
            proto = next((b for b in per_dev if b is not None), None)
            if proto is None:           # whole bucket offline this round
                continue
            zero = _zeros_like_batches(proto)
            batches = _stack_trees([b if b is not None else zero
                                    for b in per_dev])
            starts = jnp.asarray([local_step[s.device_id] for s in specs],
                                 jnp.int32)
            active_j = jnp.asarray(active)
            params, opt = st["params"], st["opt"]
            if mesh is not None:
                n_pad = (-len(specs)) % n_shards
                params, opt, batches, starts, active_j = (
                    _pad_lanes(t, n_pad)
                    for t in (params, opt, batches, starts, active_j))
                params, opt, batches, starts, active_j = _shard_bucket(
                    mesh, params, opt, batches, starts, active_j)
            round_fn = _fleet_round_fn(cfg, k, lr, warmup, total_steps)
            params, opt, l = round_fn(params, opt, batches, starts, active_j)
            if mesh is not None and len(specs) % n_shards:
                # drop this round's padding before the state is carried
                # into the next round (which pads afresh)
                params, opt = (jax.tree.map(lambda x: x[:len(specs)], t)
                               for t in (params, opt))
            st["params"], st["opt"] = params, opt
            l = np.asarray(l)[:len(specs)]
            for i, s in enumerate(specs):
                if online[s.device_id]:
                    losses[s.device_id].extend(float(x) for x in l[i])
                    local_step[s.device_id] += k

        # -- reports: selected ∩ online devices ship their fresh state --
        fresh, n_late_dropped = [], 0
        for cfg, st in state.items():
            for i, s in enumerate(st["specs"]):
                d = s.device_id
                if d not in selected or not online[d]:
                    continue
                latency = traffic[d][0]
                late_by = (0 if latency <= acfg.deadline_s
                           else int(math.ceil(latency / acfg.deadline_s)) - 1)
                if late_by and acfg.deadline_policy in ("drop", "standby"):
                    n_late_dropped += 1
                    lost_reports += 1
                    continue
                report = {
                    "device_id": d,
                    "bucket": cfg,
                    "params": jax.tree.map(lambda x: x[i], st["params"]),
                    "trained_round": r,
                    "arrival_round": r + late_by,
                    "bytes": device_upload_bytes(s.comm_cfg),
                }
                if late_by:
                    pending.append(report)
                else:
                    fresh.append(report)

        # -- merge everything deliverable this round, per bucket --
        matured = [p for p in pending if p["arrival_round"] <= r]
        pending = [p for p in pending if p["arrival_round"] > r]
        deliverable = fresh + matured
        per_bucket: Dict = {}
        for rep in deliverable:
            rep["staleness"] = r - rep["trained_round"]
            per_bucket.setdefault(rep["bucket"], []).append(rep)
        round_bytes = 0
        for cfg, reps in per_bucket.items():
            aggregator.merge_round(cfg, reps)
            dev_bytes = sum(rep["bytes"] for rep in reps)
            if acfg.hierarchical:
                # devices -> sub-server rides the cheap edge tier; only
                # the bucket aggregate crosses the global link (billed at
                # the bucket's configured full-size model, Fig. 8 style)
                comm_edge += dev_bytes
                agg_bytes = model_param_bytes(
                    by_id[reps[0]["device_id"]].comm_cfg)
                comm_global += agg_bytes
                round_bytes += agg_bytes
            else:
                comm_global += dev_bytes
                round_bytes += dev_bytes

        stale_merged = len(matured)
        n_online = sum(online.values())
        n_reported = len(deliverable)
        rounds_log.append({
            "round": r,
            "online": n_online,
            "selected": len(selected),
            "reported": n_reported,
            "stale_merged": stale_merged,
            "late_dropped": n_late_dropped,
            "participation_rate": round(n_reported / n_fleet, 4),
            "comm_bytes": int(round_bytes),
        })
        log(f"round {r}: online {n_online}/{n_fleet}, selected "
            f"{len(selected)}, reported {n_reported} "
            f"({stale_merged} stale, {n_late_dropped} late-dropped), "
            f"{round_bytes} B")

    lost_reports += len(pending)     # never matured before the run ended
    staleness = aggregator.merged_staleness
    uploads = []
    for s in fleet:
        i = state[s.cfg]["specs"].index(s)
        uploads.append(_upload(
            s, corpus, jax.tree.map(lambda x: x[i], state[s.cfg]["params"]),
            np.asarray(losses[s.device_id], np.float32)))

    fleet_report = {
        "mode": "hierarchical" if acfg.hierarchical else "flat",
        "rounds": rounds_log,
        "participation_rate": round(
            float(np.mean([x["participation_rate"] for x in rounds_log])), 4),
        "staleness_hist": aggregator.staleness_histogram(),
        "staleness_p95": (float(np.percentile(staleness, 95))
                          if staleness else 0.0),
        "merged_reports": len(staleness),
        "lost_reports": int(lost_reports),
        "comm_bytes_global": int(comm_global),
        "comm_bytes_edge": int(comm_edge),
        "aggregates": {cfg.name: aggregator.aggregates[cfg]
                       for cfg in aggregator.aggregates},
        "n_hosts": n_shards,
    }
    return uploads, fleet_report
