"""Edge-device simulation: local on-device LLM training (paper §IV.A).

Each device independently picks an on-device LLM family suited to its
hardware (paper: GPT-2, GPT-2-Medium, TinyLlama, OLMo-1.2B, BLOOM-1.1B),
trains it on private local data to convergence, and uploads it **once**
(one-shot FL, Eq. 5) together with a low-rank data embedding for
clustering.

The fleet is simulated in-process.  Two compiled hot paths (see
docs/loops.md):

* ``train_device`` runs the whole local epoch as ONE ``lax.scan``-ed
  XLA program over pre-generated stacked batches — a single host sync
  per epoch instead of one per step;
* ``train_fleet`` buckets devices by ``ModelConfig`` and ``jax.vmap``s
  the scanned epoch over the device axis, so N same-arch devices train
  as one compiled program instead of N sequential loops.

Communication cost accounting uses the *configured* model's true
parameter count (so Fig. 8-style numbers reflect the paper's device
models even when the simulated training runs reduced CPU variants).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedCorpus
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule, scan_epoch
from repro.utils.pytree import tree_bytes


@dataclasses.dataclass
class DeviceSpec:
    device_id: int
    cfg: ModelConfig            # the on-device LLM this device runs
    arch_id: int                # index into the device-model family list
    domain_id: int              # ground-truth knowledge domain (hidden)
    # full-size variant of ``cfg`` when the simulation trains a reduced
    # CPU stand-in; comm-cost accounting (Fig. 8) bills this one.
    full_cfg: Optional[ModelConfig] = None

    @property
    def comm_cfg(self) -> ModelConfig:
        return self.full_cfg or self.cfg


@functools.lru_cache(maxsize=64)
def model_param_bytes(cfg: ModelConfig) -> int:
    """Weight bytes of ``cfg`` at its configured dtype, from abstract
    shapes only (no allocation — works for 100B+ configs)."""
    shapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    return tree_bytes(shapes)


def device_upload_bytes(cfg: ModelConfig, embedding_dim: int = 32) -> int:
    """One-shot upload = model weights + the tiny data embedding (Eq. 5).

    Billed from the configured ``ModelConfig``'s true parameter count,
    NOT from whatever reduced variant the simulation happens to train.
    """
    return model_param_bytes(cfg) + embedding_dim * 4


# ---------------------------------------------------------------------------
# compiled local-training epochs
# ---------------------------------------------------------------------------

def _step_core(cfg: ModelConfig) -> Callable:
    """The one local-training step: shared by the per-step reference
    loop and the scanned epoch, so the two paths cannot diverge."""

    def step(params, opt, b, lr_now):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, b), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=lr_now)
        return params, opt, loss

    return step


def _epoch_core(cfg: ModelConfig, steps: int, lr: float,
                warmup: int) -> Callable:
    """Un-jitted scanned epoch: (params, opt, stacked batches) ->
    (params, opt, per-step losses).  The lr schedule is evaluated inside
    the scan from the step counter."""
    sched = cosine_schedule(lr, steps, warmup=warmup)
    step = _step_core(cfg)

    def carry_step(carry, b, lr_now):
        params, opt, loss = step(*carry, b, lr_now)
        return (params, opt), loss

    scanned = scan_epoch(carry_step, sched, steps)

    def epoch(params, opt, batches):
        (params, opt), losses = scanned((params, opt), batches)
        return params, opt, losses

    return epoch


@functools.lru_cache(maxsize=64)
def _device_epoch_fn(cfg: ModelConfig, steps: int, lr: float, warmup: int):
    return jax.jit(_epoch_core(cfg, steps, lr, warmup),
                   donate_argnums=(0, 1))


@functools.lru_cache(maxsize=64)
def _fleet_epoch_fn(cfg: ModelConfig, steps: int, lr: float, warmup: int):
    """The scanned epoch vmapped over a leading device axis — one
    compiled program trains every same-arch device in the bucket."""
    return jax.jit(jax.vmap(_epoch_core(cfg, steps, lr, warmup)),
                   donate_argnums=(0, 1))


@functools.lru_cache(maxsize=64)
def _device_step_fn(cfg: ModelConfig):
    """Per-step reference path (kept for equivalence tests and the
    fleet-scaling benchmark baseline)."""
    return jax.jit(_step_core(cfg))


def _device_init(spec: DeviceSpec, seed: int, state_policy: str = ""):
    params = M.init_params(
        jax.random.PRNGKey(seed * 100003 + spec.device_id), spec.cfg)
    return params, adamw_init(params, policy=state_policy)


def _upload(spec: DeviceSpec, corpus: FederatedCorpus, params,
            losses) -> Dict:
    return {
        "params": params,
        "embedding": corpus.device_embedding(spec.device_id),
        "losses": [float(x) for x in np.asarray(losses)],
        "upload_bytes": device_upload_bytes(spec.comm_cfg),
        "arch_id": spec.arch_id,
        "device_id": spec.device_id,
    }


def train_device(spec: DeviceSpec, corpus: FederatedCorpus, *, steps: int,
                 batch: int, seq_len: int, lr: float = 3e-3,
                 seed: int = 0, compiled: bool = True,
                 state_policy: str = "") -> Dict:
    """Local training.  Returns {"params", "embedding", "losses", ...}.

    ``compiled=True`` (default) runs the epoch as one scanned program;
    ``compiled=False`` keeps the historical per-step loop (one host sync
    per step) for equivalence tests and benchmarks.

    ``state_policy`` ('' | 'bf16' | 'int8') sets the AdamW moment
    storage (see ``repro.optim.adamw.resolve_moment_policy``); the
    scanned epoch needs no plumbing — it retraces per state structure.
    """
    params, opt = _device_init(spec, seed, state_policy)
    warmup = max(steps // 20, 1)
    if compiled:
        batches = corpus.device_batches(spec.device_id, steps, batch, seq_len)
        epoch = _device_epoch_fn(spec.cfg, steps, lr, warmup)
        params, opt, losses = epoch(params, opt, batches)
        return _upload(spec, corpus, params, losses)

    sched = cosine_schedule(lr, steps, warmup=warmup)
    step_fn = _device_step_fn(spec.cfg)
    losses = []
    for s in range(steps):
        b = corpus.device_batch(spec.device_id, batch, seq_len, step=s)
        params, opt, loss = step_fn(params, opt, b, sched(s))
        losses.append(float(loss))
    return _upload(spec, corpus, params, losses)


def train_fleet(fleet: Sequence[DeviceSpec], corpus: FederatedCorpus, *,
                steps: int, batch: int, seq_len: int, lr: float = 3e-3,
                seed: int = 0, state_policy: str = "") -> List[Dict]:
    """Arch-bucketed compiled fleet training.

    Groups the fleet by ``ModelConfig``, stacks each bucket's init
    params / optimizer state / pre-generated batch streams along a new
    device axis, and runs the vmapped scanned epoch once per bucket.
    Returns uploads in the fleet's original order, identical to calling
    ``train_device`` per spec (same seeds, same batches).

    ``state_policy`` quantizes each device's stacked AdamW moments
    ('bf16' halves them; 'int8' quarters v) so a host fits measurably
    more devices per bucket at equal bytes — the paper's
    resource-constrained edge fleet at scale.
    """
    buckets: Dict[ModelConfig, List[DeviceSpec]] = {}
    for spec in fleet:
        buckets.setdefault(spec.cfg, []).append(spec)

    uploads: Dict[int, Dict] = {}
    warmup = max(steps // 20, 1)
    for cfg, specs in buckets.items():
        inits = [_device_init(s, seed, state_policy) for s in specs]
        params = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[p for p, _ in inits])
        opt = jax.tree.map(lambda *xs: jnp.stack(xs), *[o for _, o in inits])
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[corpus.device_batches(s.device_id, steps, batch, seq_len)
              for s in specs])
        epoch = _fleet_epoch_fn(cfg, steps, lr, warmup)
        params, _, losses = epoch(params, opt, batches)
        losses = np.asarray(losses)          # one host sync per bucket
        for i, spec in enumerate(specs):
            uploads[spec.device_id] = _upload(
                spec, corpus, jax.tree.map(lambda x: x[i], params), losses[i])

    return [uploads[spec.device_id] for spec in fleet]
