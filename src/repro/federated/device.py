"""Edge-device simulation: local on-device LLM training (paper §IV.A).

Each device independently picks an on-device LLM family suited to its
hardware (paper: GPT-2, GPT-2-Medium, TinyLlama, OLMo-1.2B, BLOOM-1.1B),
trains it on private local data to convergence, and uploads it **once**
(one-shot FL, Eq. 5) together with a low-rank data embedding for
clustering.

The fleet is simulated in-process.  Communication cost accounting uses
the *configured* model's true parameter count (so Fig. 8-style numbers
reflect the paper's device models even when the simulated training runs
reduced CPU variants).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedCorpus
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.utils.pytree import tree_bytes


@dataclasses.dataclass
class DeviceSpec:
    device_id: int
    cfg: ModelConfig            # the on-device LLM this device runs
    arch_id: int                # index into the device-model family list
    domain_id: int              # ground-truth knowledge domain (hidden)


def device_upload_bytes(params, embedding_dim: int = 32) -> int:
    """One-shot upload = model weights + the tiny data embedding (Eq. 5)."""
    return tree_bytes(params) + embedding_dim * 4


@functools.lru_cache(maxsize=64)
def _device_step_fn(cfg: ModelConfig):
    """One jitted train step per config — devices sharing a model family
    (the common case in a fleet) reuse the compiled step."""

    @jax.jit
    def step_fn(params, opt, b, lr_now):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, b), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=lr_now)
        return params, opt, loss

    return step_fn


def train_device(spec: DeviceSpec, corpus: FederatedCorpus, *, steps: int,
                 batch: int, seq_len: int, lr: float = 3e-3,
                 seed: int = 0) -> Dict:
    """Local training loop.  Returns {"params", "embedding", "losses", ...}."""
    cfg = spec.cfg
    params = M.init_params(jax.random.PRNGKey(seed * 100003 + spec.device_id), cfg)
    opt = adamw_init(params)
    sched = cosine_schedule(lr, steps, warmup=max(steps // 20, 1))
    step_fn = _device_step_fn(cfg)

    losses = []
    for s in range(steps):
        b = corpus.device_batch(spec.device_id, batch, seq_len, step=s)
        params, opt, loss = step_fn(params, opt, b, sched(s))
        losses.append(float(loss))

    return {
        "params": params,
        "embedding": corpus.device_embedding(spec.device_id),
        "losses": losses,
        "upload_bytes": device_upload_bytes(params),
        "arch_id": spec.arch_id,
        "device_id": spec.device_id,
    }
