"""Edge-device simulation: local on-device LLM training (paper §IV.A).

Each device independently picks an on-device LLM family suited to its
hardware (paper: GPT-2, GPT-2-Medium, TinyLlama, OLMo-1.2B, BLOOM-1.1B),
trains it on private local data to convergence, and uploads it **once**
(one-shot FL, Eq. 5) together with a low-rank data embedding for
clustering.

The fleet is simulated in-process.  Two compiled hot paths (see
docs/loops.md):

* ``train_device`` runs the whole local epoch as ONE ``lax.scan``-ed
  XLA program over pre-generated stacked batches — a single host sync
  per epoch instead of one per step;
* ``train_fleet`` buckets devices by ``ModelConfig`` and ``jax.vmap``s
  the scanned epoch over the device axis, so N same-arch devices train
  as one compiled program instead of N sequential loops.

Communication cost accounting uses the *configured* model's true
parameter count (so Fig. 8-style numbers reflect the paper's device
models even when the simulated training runs reduced CPU variants).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedCorpus
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule, scan_epoch
from repro.utils.pytree import tree_bytes


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """Seeded per-round traffic behaviour of one simulated edge device.

    Report latency is lognormal (``median_latency_s`` scaled by
    ``exp(sigma * N(0,1))`` — the long straggler tail real fleets show),
    each round the device is offline with probability ``dropout_p``, and
    ``avail_period``/``avail_duty`` model a battery / charging window:
    the device is only reachable during the first ``avail_duty`` rounds
    of every ``avail_period`` (0 = always available).  All draws are
    pure functions of ``(seed, device_id, round)`` — see
    ``sample_traffic`` — so fleet simulations replay bit-identically and
    a device's behaviour never depends on what the rest of the fleet did.
    """
    median_latency_s: float = 1.0
    latency_sigma: float = 0.5
    dropout_p: float = 0.0
    avail_period: int = 0
    avail_duty: int = 0


# named presets for --straggler-profile and the benchmarks
STRAGGLER_PROFILES = {
    "none": TrafficModel(),
    "mild": TrafficModel(median_latency_s=1.0, latency_sigma=0.5,
                         dropout_p=0.1),
    "harsh": TrafficModel(median_latency_s=1.5, latency_sigma=1.0,
                          dropout_p=0.3, avail_period=8, avail_duty=6),
}


@dataclasses.dataclass
class DeviceSpec:
    device_id: int
    cfg: ModelConfig            # the on-device LLM this device runs
    arch_id: int                # index into the device-model family list
    domain_id: int              # ground-truth knowledge domain (hidden)
    # full-size variant of ``cfg`` when the simulation trains a reduced
    # CPU stand-in; comm-cost accounting (Fig. 8) bills this one.
    full_cfg: Optional[ModelConfig] = None
    # straggler/dropout behaviour for async rounds (None = ideal link)
    traffic: Optional[TrafficModel] = None

    @property
    def comm_cfg(self) -> ModelConfig:
        return self.full_cfg or self.cfg


def sample_traffic(spec: DeviceSpec, round_idx: int, seed: int):
    """Deterministic ``(latency_s, online)`` draw for (device, round).

    Keyed on ``(seed, device_id, round)`` only — independent of fleet
    history, so a device that dropped out rejoins with the identical
    latency/dropout stream it would always have had."""
    tm = spec.traffic or TrafficModel()
    if tm.avail_period and (round_idx % tm.avail_period) >= tm.avail_duty:
        return 0.0, False
    rng = np.random.default_rng(
        (seed, 7_700_000 + spec.device_id, round_idx))
    dropped = bool(rng.random() < tm.dropout_p)
    latency = float(tm.median_latency_s * np.exp(tm.latency_sigma *
                                                 rng.standard_normal()))
    return latency, not dropped


@functools.lru_cache(maxsize=64)
def model_param_bytes(cfg: ModelConfig) -> int:
    """Weight bytes of ``cfg`` at its configured dtype, from abstract
    shapes only (no allocation — works for 100B+ configs)."""
    shapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    return tree_bytes(shapes)


def device_upload_bytes(cfg: ModelConfig, embedding_dim: int = 32) -> int:
    """One-shot upload = model weights + the tiny data embedding (Eq. 5).

    Billed from the configured ``ModelConfig``'s true parameter count,
    NOT from whatever reduced variant the simulation happens to train.
    """
    return model_param_bytes(cfg) + embedding_dim * 4


# ---------------------------------------------------------------------------
# compiled local-training epochs
# ---------------------------------------------------------------------------

def _step_core(cfg: ModelConfig) -> Callable:
    """The one local-training step: shared by the per-step reference
    loop and the scanned epoch, so the two paths cannot diverge."""

    def step(params, opt, b, lr_now):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, b), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=lr_now)
        return params, opt, loss

    return step


def _epoch_core(cfg: ModelConfig, steps: int, lr: float, warmup: int,
                total_steps: Optional[int] = None) -> Callable:
    """Un-jitted scanned epoch: (params, opt, stacked batches[, start])
    -> (params, opt, per-step losses).  The lr schedule is evaluated
    inside the scan from the step counter.

    ``total_steps`` sets the schedule horizon when this epoch is one
    *round* of a longer run (async fleet rounds); ``start`` then offsets
    the counter, so round ``r`` of ``k`` steps computes exactly steps
    ``[r*k, (r+1)*k)`` of the equivalent single-scan epoch."""
    sched = cosine_schedule(lr, total_steps or steps, warmup=warmup)
    step = _step_core(cfg)

    def carry_step(carry, b, lr_now):
        params, opt, loss = step(*carry, b, lr_now)
        return (params, opt), loss

    scanned = scan_epoch(carry_step, sched, steps)

    def epoch(params, opt, batches, start=0):
        (params, opt), losses = scanned((params, opt), batches, start)
        return params, opt, losses

    return epoch


@functools.lru_cache(maxsize=64)
def _device_epoch_fn(cfg: ModelConfig, steps: int, lr: float, warmup: int):
    return jax.jit(_epoch_core(cfg, steps, lr, warmup),
                   donate_argnums=(0, 1))


@functools.lru_cache(maxsize=64)
def _fleet_epoch_fn(cfg: ModelConfig, steps: int, lr: float, warmup: int):
    """The scanned epoch vmapped over a leading device axis — one
    compiled program trains every same-arch device in the bucket."""
    return jax.jit(jax.vmap(
        lambda p, o, b: _epoch_core(cfg, steps, lr, warmup)(p, o, b)),
        donate_argnums=(0, 1))


@functools.lru_cache(maxsize=64)
def _fleet_round_fn(cfg: ModelConfig, steps: int, lr: float, warmup: int,
                    total_steps: int):
    """One async *round* for a whole arch bucket: the scanned epoch
    vmapped over devices, with per-device schedule offsets (``start``,
    each device's local step into the ``total_steps`` horizon) and an
    ``active`` mask — offline devices' params/opt pass through untouched
    and their loss lanes come back NaN, so the round compiles ONCE per
    bucket shape regardless of which subset of devices is online."""
    epoch = _epoch_core(cfg, steps, lr, warmup, total_steps=total_steps)

    def device_round(params, opt, batches, start, active):
        p2, o2, losses = epoch(params, opt, batches, start)
        sel = lambda new, old: jnp.where(active, new, old)
        return (jax.tree.map(sel, p2, params), jax.tree.map(sel, o2, opt),
                jnp.where(active, losses, jnp.nan))

    return jax.jit(jax.vmap(device_round), donate_argnums=(0, 1))


@functools.lru_cache(maxsize=64)
def _device_step_fn(cfg: ModelConfig):
    """Per-step reference path (kept for equivalence tests and the
    fleet-scaling benchmark baseline)."""
    return jax.jit(_step_core(cfg))


def _device_init(spec: DeviceSpec, seed: int, state_policy: str = ""):
    params = M.init_params(
        jax.random.PRNGKey(seed * 100003 + spec.device_id), spec.cfg)
    return params, adamw_init(params, policy=state_policy)


def _upload(spec: DeviceSpec, corpus: FederatedCorpus, params,
            losses) -> Dict:
    return {
        "params": params,
        "embedding": corpus.device_embedding(spec.device_id),
        "losses": [float(x) for x in np.asarray(losses)],
        "upload_bytes": device_upload_bytes(spec.comm_cfg),
        "arch_id": spec.arch_id,
        "device_id": spec.device_id,
    }


def train_device(spec: DeviceSpec, corpus: FederatedCorpus, *, steps: int,
                 batch: int, seq_len: int, lr: float = 3e-3,
                 seed: int = 0, compiled: bool = True,
                 state_policy: str = "") -> Dict:
    """Local training.  Returns {"params", "embedding", "losses", ...}.

    ``compiled=True`` (default) runs the epoch as one scanned program;
    ``compiled=False`` keeps the historical per-step loop (one host sync
    per step) for equivalence tests and benchmarks.

    ``state_policy`` ('' | 'bf16' | 'int8') sets the AdamW moment
    storage (see ``repro.optim.adamw.resolve_moment_policy``); the
    scanned epoch needs no plumbing — it retraces per state structure.
    """
    params, opt = _device_init(spec, seed, state_policy)
    warmup = max(steps // 20, 1)
    if compiled:
        batches = corpus.device_batches(spec.device_id, steps, batch, seq_len)
        epoch = _device_epoch_fn(spec.cfg, steps, lr, warmup)
        params, opt, losses = epoch(params, opt, batches)
        return _upload(spec, corpus, params, losses)

    sched = cosine_schedule(lr, steps, warmup=warmup)
    step_fn = _device_step_fn(spec.cfg)
    losses = []
    for s in range(steps):
        b = corpus.device_batch(spec.device_id, batch, seq_len, step=s)
        params, opt, loss = step_fn(params, opt, b, sched(s))
        losses.append(float(loss))
    return _upload(spec, corpus, params, losses)


def fleet_buckets(fleet: Sequence[DeviceSpec]
                  ) -> Dict[ModelConfig, List[DeviceSpec]]:
    """Group the fleet by (hashable) ``ModelConfig``, preserving order."""
    buckets: Dict[ModelConfig, List[DeviceSpec]] = {}
    for spec in fleet:
        buckets.setdefault(spec.cfg, []).append(spec)
    return buckets


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _pad_lanes(tree, n_pad: int):
    """Append ``n_pad`` copies of lane 0 along the stacked device axis
    (multi-host runs pad each bucket to a multiple of the host count;
    padded lanes are discarded after the round)."""
    if n_pad == 0:
        return tree
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (n_pad,) + x.shape[1:])]), tree)


def _shard_bucket(mesh, *trees):
    """Lay a bucket's stacked trees out over the ``("hosts",)`` mesh:
    the leading device axis shards over hosts (see
    ``sharding.rules.fleet_specs``), so fleet size scales with hosts —
    each host holds ``n_devices / n_hosts`` device states."""
    from repro.sharding import rules
    return tuple(
        jax.device_put(t, rules.named(mesh, rules.fleet_specs(t, mesh)))
        for t in trees)


def train_fleet(fleet: Sequence[DeviceSpec], corpus: FederatedCorpus, *,
                steps: int, batch: int, seq_len: int, lr: float = 3e-3,
                seed: int = 0, state_policy: str = "",
                n_hosts: int = 1, mesh=None) -> List[Dict]:
    """Arch-bucketed compiled fleet training.

    Groups the fleet by ``ModelConfig``, stacks each bucket's init
    params / optimizer state / pre-generated batch streams along a new
    device axis, and runs the vmapped scanned epoch once per bucket.
    Returns uploads in the fleet's original order, identical to calling
    ``train_device`` per spec (same seeds, same batches).

    ``state_policy`` quantizes each device's stacked AdamW moments
    ('bf16' halves them; 'int8' quarters v) so a host fits measurably
    more devices per bucket at equal bytes — the paper's
    resource-constrained edge fleet at scale.

    ``n_hosts > 1`` (or an explicit ``("hosts",)`` ``mesh``) runs each
    bucket through ``jax.pjit``: the stacked device axis is sharded over
    the mesh (buckets pad to a multiple of the host count with discarded
    lanes), so the per-host resident state — and therefore the fleet
    size one simulation can hold — scales linearly with hosts.  Lanes
    are independent, so the sharded run is bit-identical to ``n_hosts=1``.
    """
    if mesh is None and n_hosts > 1:
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(n_hosts)
    n_shards = mesh.shape["hosts"] if mesh is not None else 1

    uploads: Dict[int, Dict] = {}
    warmup = max(steps // 20, 1)
    for cfg, specs in fleet_buckets(fleet).items():
        inits = [_device_init(s, seed, state_policy) for s in specs]
        params = _stack_trees([p for p, _ in inits])
        opt = _stack_trees([o for _, o in inits])
        batches = _stack_trees(
            [corpus.device_batches(s.device_id, steps, batch, seq_len)
             for s in specs])
        if mesh is not None:
            n_pad = (-len(specs)) % n_shards
            params, opt, batches = (_pad_lanes(t, n_pad)
                                    for t in (params, opt, batches))
            params, opt, batches = _shard_bucket(mesh, params, opt, batches)
        epoch = _fleet_epoch_fn(cfg, steps, lr, warmup)
        params, _, losses = epoch(params, opt, batches)
        losses = np.asarray(losses)          # one host sync per bucket
        for i, spec in enumerate(specs):
            uploads[spec.device_id] = _upload(
                spec, corpus, jax.tree.map(lambda x: x[i], params), losses[i])

    return [uploads[spec.device_id] for spec in fleet]
