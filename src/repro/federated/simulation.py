"""End-to-end DeepFusion simulation driver (used by examples/benchmarks).

Builds the federated corpus, trains the device fleet locally, runs the
three-phase server pipeline, and evaluates the resulting global MoE on
per-domain held-out data (token perplexity Eq. 3 + token accuracy —
the paper's Tables I/II metrics).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedCorpus
from repro.federated.async_fleet import train_fleet_async
from repro.federated.device import (STRAGGLER_PROFILES, DeviceSpec,
                                    TrafficModel, train_fleet)
from repro.federated.server import DeepFusionServer, ServerConfig
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class SimulationConfig:
    n_devices: int = 8
    n_domains: int = 4
    vocab: int = 256
    seq_len: int = 64
    device_steps: int = 40
    device_batch: int = 8
    seed: int = 0
    alpha_noniid: float = 0.3


@functools.lru_cache(maxsize=64)
def _eval_batch_fn(cfg: ModelConfig, mesh):
    @jax.jit
    def eval_batch(params, b):
        _, metrics = M.loss_fn(params, cfg, b, mesh=mesh)
        return metrics["nll"], metrics["tokens"], metrics["accuracy"]

    return eval_batch


def evaluate_model(params, cfg: ModelConfig, corpus: FederatedCorpus, *,
                   seq_len: int, batch: int = 8, n_batches: int = 4,
                   mesh=None) -> Dict[str, float]:
    """Per-domain + overall token perplexity (Eq. 3) and accuracy."""
    eval_batch = _eval_batch_fn(cfg, mesh)
    out = {}
    nll_all, tok_all, acc_all = 0.0, 0.0, []
    for d in range(len(corpus.domains)):
        nll, tok, accs = 0.0, 0.0, []
        for i in range(n_batches):
            b = corpus.domain_eval_batch(d, batch, seq_len, seed_salt=i)
            n, t, a = eval_batch(params, b)
            nll += float(n); tok += float(t); accs.append(float(a))
        out[f"ppl_domain{d}"] = math.exp(nll / max(tok, 1.0))
        out[f"logppl_domain{d}"] = nll / max(tok, 1.0)
        out[f"acc_domain{d}"] = float(np.mean(accs))
        nll_all += nll; tok_all += tok; acc_all.extend(accs)
    out["log_ppl"] = nll_all / max(tok_all, 1.0)
    out["ppl"] = math.exp(out["log_ppl"])
    out["accuracy"] = float(np.mean(acc_all))
    return out


def build_fleet(sim: SimulationConfig, corpus: FederatedCorpus,
                device_cfgs: Sequence[ModelConfig], *,
                full_cfgs: Optional[Sequence[ModelConfig]] = None,
                traffic=None) -> List[DeviceSpec]:
    """``full_cfgs`` (parallel to ``device_cfgs``): the full-size model
    each family stands in for, so comm-cost accounting bills the paper's
    device LLMs even when the simulation trains reduced CPU variants.

    ``traffic``: a ``TrafficModel`` (or a ``STRAGGLER_PROFILES`` name)
    applied to every device, for async-round straggler simulation."""
    if full_cfgs is not None and len(full_cfgs) != len(device_cfgs):
        # fail here with names, not deep inside the fleet loop with an
        # opaque IndexError on some sampled arch id
        missing = [c.name for c in device_cfgs[len(full_cfgs):]] \
            if len(full_cfgs) < len(device_cfgs) else []
        raise ValueError(
            f"full_cfgs has {len(full_cfgs)} entries for "
            f"{len(device_cfgs)} device families "
            f"({[c.name for c in device_cfgs]}); it must be parallel to "
            f"device_cfgs" +
            (f" — missing full-size models for {missing}" if missing else ""))
    if isinstance(traffic, str):
        try:
            traffic = STRAGGLER_PROFILES[traffic]
        except KeyError:
            raise ValueError(
                f"unknown straggler profile {traffic!r}; pick one of "
                f"{sorted(STRAGGLER_PROFILES)}") from None
    rng = np.random.default_rng(sim.seed + 42)
    fleet = []
    for n in range(sim.n_devices):
        arch = int(rng.integers(len(device_cfgs)))
        fleet.append(DeviceSpec(
            device_id=n, cfg=device_cfgs[arch], arch_id=arch,
            domain_id=int(corpus.device_domain[n]),
            full_cfg=full_cfgs[arch] if full_cfgs else None,
            traffic=traffic))
    return fleet


def run_deepfusion(sim: SimulationConfig, server_cfg: ServerConfig,
                   device_cfgs: Sequence[ModelConfig], *,
                   log: Callable[[str], None] = print,
                   uploads=None, corpus=None, full_cfgs=None,
                   traffic=None, n_hosts: int = 1):
    """Returns (moe_params, report) — report carries metrics + comm cost.

    ``full_cfgs`` optionally maps each device family to the full-size
    model it stands in for (comm-cost billing; see build_fleet).

    ``server_cfg.schedule`` (an ``AsyncFleetConfig``) switches local
    training from the one-shot synchronous ``train_fleet`` to async
    participation rounds (``train_fleet_async``); ``traffic`` sets every
    device's straggler model (see ``build_fleet``) and ``n_hosts``
    shards the stacked fleet state over a ``("hosts",)`` mesh.  The
    async round log lands in ``report["fleet"]``."""
    corpus = corpus or FederatedCorpus.build(
        seed=sim.seed, n_devices=sim.n_devices, n_domains=sim.n_domains,
        vocab=sim.vocab, alpha=sim.alpha_noniid)
    fleet_report = None
    if uploads is None:
        fleet = build_fleet(sim, corpus, device_cfgs, full_cfgs=full_cfgs,
                            traffic=traffic)
        if server_cfg.schedule is not None:
            acfg = server_cfg.schedule
            if acfg.steps_per_round <= 0:
                # 0 = "derive from the sim": split device_steps evenly
                acfg = dataclasses.replace(
                    acfg, steps_per_round=max(1, sim.device_steps
                                              // acfg.rounds))
            uploads, fleet_report = train_fleet_async(
                fleet, corpus, acfg, batch=sim.device_batch,
                seq_len=sim.seq_len, seed=sim.seed, n_hosts=n_hosts,
                log=log)
        else:
            # arch-bucketed vmapped fleet training: one compiled program
            # per model family instead of n_devices sequential loops
            uploads = train_fleet(fleet, corpus, steps=sim.device_steps,
                                  batch=sim.device_batch,
                                  seq_len=sim.seq_len, seed=sim.seed,
                                  n_hosts=n_hosts)
        for spec, up in zip(fleet, uploads):
            if not up["losses"]:
                log(f"device {spec.device_id} (arch {spec.arch_id}, "
                    f"domain {spec.domain_id}): never online")
                continue
            log(f"device {spec.device_id} (arch {spec.arch_id}, "
                f"domain {spec.domain_id}): loss "
                f"{up['losses'][0]:.3f}->{up['losses'][-1]:.3f}")
    server = DeepFusionServer(server_cfg, corpus, device_cfgs, log=log)
    moe_params, report = server.run(uploads)
    metrics = evaluate_model(moe_params, server_cfg.moe_cfg, corpus,
                             seq_len=sim.seq_len)
    report["metrics"] = metrics
    report["uploads"] = uploads
    report["corpus"] = corpus
    if fleet_report is not None:
        report["fleet"] = fleet_report
    if report.get("distill_hists"):
        finals = ", ".join(f"{h[-1]:.3f}" for h in report["distill_hists"])
        log(f"Phase II final losses per proxy: [{finals}]")
    if report.get("tune_hist"):
        log(f"Phase III tune: {report['tune_hist'][0]:.3f}->"
            f"{report['tune_hist'][-1]:.3f} over {len(report['tune_hist'])} steps")
    log(f"global MoE: log-ppl {metrics['log_ppl']:.4f} "
        f"acc {metrics['accuracy']:.3f}")
    return moe_params, report
