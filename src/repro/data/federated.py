"""Federated data layout: N edge devices over K knowledge domains.

Each device draws from a (usually single) domain — the paper's setting
where a device's private data reflects one local application.  Data
volume per device is random and uneven (paper §V.A "distributed randomly
and unevenly").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.data.synthetic import (DomainSpec, batch_from_tokens,
                                  domain_embedding, make_domains,
                                  sample_tokens)


def dirichlet_partition(rng: np.random.Generator, n_devices: int,
                        n_domains: int, alpha: float = 0.3) -> np.ndarray:
    """Assign each device a primary domain; alpha controls skew."""
    weights = rng.dirichlet(np.full(n_domains, alpha), size=n_devices)
    return np.argmax(weights, axis=1).astype(np.int32)


@dataclasses.dataclass
class FederatedCorpus:
    domains: List[DomainSpec]
    device_domain: np.ndarray        # (N,) domain id per device
    device_scale: np.ndarray         # (N,) relative data volume
    seed: int

    @classmethod
    def build(cls, *, seed: int, n_devices: int, n_domains: int, vocab: int,
              alpha: float = 0.3):
        rng = np.random.default_rng(seed)
        domains = make_domains(seed, n_domains, vocab)
        assignment = dirichlet_partition(rng, n_devices, n_domains, alpha)
        scale = rng.lognormal(0.0, 0.5, size=n_devices).astype(np.float32)
        return cls(domains, assignment, scale, seed)

    @property
    def n_devices(self) -> int:
        return len(self.device_domain)

    def device_rng(self, device: int, salt: int = 0) -> np.random.Generator:
        return np.random.default_rng((self.seed, device, salt))

    def _device_tokens(self, device: int, batch: int, seq_len: int,
                       step: int = 0) -> np.ndarray:
        dom = self.domains[int(self.device_domain[device])]
        rng = self.device_rng(device, step + 1)
        return sample_tokens(dom, rng, batch, seq_len)

    def device_batch(self, device: int, batch: int, seq_len: int,
                     step: int = 0) -> Dict:
        return batch_from_tokens(self._device_tokens(device, batch, seq_len,
                                                     step))

    def device_batches(self, device: int, steps: int, batch: int,
                       seq_len: int, start: int = 0) -> Dict:
        """Pre-generates a full local-training epoch for one device as
        stacked ``(steps, B, S)`` arrays.  Step ``s`` equals
        ``device_batch(device, batch, seq_len, step=start + s)`` exactly,
        so the scan drivers reproduce the per-step loop bit-for-bit.

        ``start`` resumes the stream mid-epoch: the async fleet driver
        feeds each round the slice ``[local_step, local_step + k)`` of a
        device's stream, and because every step is keyed on
        ``(corpus seed, device, step)`` alone, a device that sat out a
        round consumes the *identical* continuation when it rejoins."""
        toks = np.stack([self._device_tokens(device, batch, seq_len,
                                             step=start + s)
                         for s in range(steps)])
        return batch_from_tokens(toks)

    def device_embedding(self, device: int, dim: int = 32) -> np.ndarray:
        dom = self.domains[int(self.device_domain[device])]
        return domain_embedding(dom, self.device_rng(device, 7777), dim)

    def domain_eval_batch(self, domain_id: int, batch: int, seq_len: int,
                          seed_salt: int = 0) -> Dict:
        rng = np.random.default_rng((self.seed, 999_000 + domain_id, seed_salt))
        return batch_from_tokens(
            sample_tokens(self.domains[domain_id], rng, batch, seq_len))

    def _mixed_tokens(self, batch: int, seq_len: int,
                      seed_salt: int = 0) -> np.ndarray:
        rng = np.random.default_rng((self.seed, 555_000, seed_salt))
        per = max(batch // len(self.domains), 1)
        parts = []
        for d in self.domains:
            parts.append(sample_tokens(d, rng, per, seq_len))
        toks = np.concatenate(parts, 0)[:batch]
        if len(toks) < batch:  # pad by repeating
            reps = -(-batch // len(toks))
            toks = np.concatenate([toks] * reps, 0)[:batch]
        return toks

    def mixed_eval_batch(self, batch: int, seq_len: int, seed_salt: int = 0):
        """Server-side public benchmark data (paper assumes HF/GitHub data)."""
        return batch_from_tokens(self._mixed_tokens(batch, seq_len, seed_salt))

    def mixed_eval_batches(self, steps: int, batch: int, seq_len: int,
                           seed_salt0: int = 0) -> Dict:
        """Stacked ``(steps, B, S)`` server-data epoch; step ``s`` equals
        ``mixed_eval_batch(batch, seq_len, seed_salt=seed_salt0 + s)``."""
        toks = np.stack([self._mixed_tokens(batch, seq_len, seed_salt0 + s)
                         for s in range(steps)])
        return batch_from_tokens(toks)
