from repro.data.synthetic import DomainSpec, make_domains, sample_tokens, domain_embedding
from repro.data.federated import FederatedCorpus, dirichlet_partition

__all__ = ["DomainSpec", "make_domains", "sample_tokens", "domain_embedding",
           "FederatedCorpus", "dirichlet_partition"]
