"""Synthetic multi-domain corpora.

MMedBench / FinQA are not available offline, so we build corpora with the
*statistical structure the paper's pipeline needs*: K distinguishable
knowledge domains (medical specialities / finance topics in the paper),
each a sparse bigram Markov chain over the vocabulary.  Domains are
learnable (low entropy given the previous token) and mutually
distinguishable (disjoint-ish transition supports), so:

* an on-device LLM trained on one domain genuinely acquires
  domain-specific knowledge (its perplexity drops on that domain only);
* clustering by data embeddings recovers the domain partition;
* the global MoE's experts can specialise per domain.

``domain_embedding`` plays the role of the paper's MiniLM low-rank data
embeddings e_n (§IV.B): a deterministic random projection of the domain's
unigram distribution + noise.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DomainSpec:
    domain_id: int
    vocab: int
    branching: int
    succ: np.ndarray      # (vocab, branching) successor token ids
    probs: np.ndarray     # (vocab, branching) transition probabilities
    unigram: np.ndarray   # (vocab,) stationary-ish distribution


def make_domains(seed: int, n_domains: int, vocab: int,
                 branching: int = 8) -> List[DomainSpec]:
    rng = np.random.default_rng(seed)
    domains = []
    for d in range(n_domains):
        succ = rng.integers(0, vocab, size=(vocab, branching))
        raw = rng.dirichlet(np.full(branching, 0.5), size=vocab)
        # each domain also has a preferred token band -> distinguishable
        band = rng.permutation(vocab)[: vocab // 4]
        unigram = np.full(vocab, 1.0)
        unigram[band] += 8.0
        unigram /= unigram.sum()
        domains.append(DomainSpec(d, vocab, branching, succ.astype(np.int32),
                                  raw.astype(np.float32),
                                  unigram.astype(np.float32)))
    return domains


def sample_tokens(domain: DomainSpec, rng: np.random.Generator,
                  batch: int, seq_len: int) -> np.ndarray:
    """Sample (batch, seq_len+1) token sequences from the domain chain."""
    out = np.empty((batch, seq_len + 1), np.int32)
    cur = rng.choice(domain.vocab, size=batch, p=domain.unigram)
    out[:, 0] = cur
    for t in range(1, seq_len + 1):
        u = rng.random(batch)
        cdf = np.cumsum(domain.probs[cur], axis=1)
        choice = (u[:, None] > cdf).sum(axis=1).clip(max=domain.branching - 1)
        cur = domain.succ[cur, choice]
        out[:, t] = cur
    return out


def batch_from_tokens(tokens: np.ndarray):
    """(..., S+1) -> {"tokens": (...,S), "labels": (...,S)} next-token
    setup.  Rank-agnostic: works for a single (B, S+1) batch and for
    (T, B, S+1) stacked epochs alike."""
    return {"tokens": jnp.asarray(tokens[..., :-1]),
            "labels": jnp.asarray(tokens[..., 1:])}


def domain_embedding(domain: DomainSpec, rng: np.random.Generator,
                     dim: int = 32, noise: float = 0.02) -> np.ndarray:
    """Low-rank data embedding (stand-in for MiniLM, paper §IV.B)."""
    proj_rng = np.random.default_rng(1234)  # shared projection across devices
    proj = proj_rng.standard_normal((domain.vocab, dim)).astype(np.float32)
    e = domain.unigram @ proj
    e = e + noise * rng.standard_normal(dim).astype(np.float32)
    return e / (np.linalg.norm(e) + 1e-9)
