"""Serving engine: continuous-batching generation over every arch family."""
from repro.serve.bucketing import bucket_for, bucket_ladder
from repro.serve.engine import (Completion, PagedServeEngine, Request,
                                ServeEngine)
from repro.serve.paged import PagedAllocator
from repro.serve.sampling import Greedy, Temperature, TopK

__all__ = ["Completion", "Greedy", "PagedAllocator", "PagedServeEngine",
           "Request", "ServeEngine", "Temperature", "TopK",
           "bucket_for", "bucket_ladder"]
