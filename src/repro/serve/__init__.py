"""Serving engine: continuous-batching generation over every arch family."""
from repro.serve.engine import Completion, Request, ServeEngine
from repro.serve.sampling import Greedy, Temperature, TopK

__all__ = ["Completion", "Greedy", "Request", "ServeEngine", "Temperature",
           "TopK"]
