"""Prompt-length bucket ladders for the chunked-prefill admission path.

Open-world traffic presents an unbounded set of prompt lengths; every
distinct length used to compile (and pin) its own prefill + admit
executable.  Bucketing rounds the *padded input length* (modality
frontend + tokens) up a small ladder of chunk-multiples, so the
engine's chunked-admission executables are keyed on the bucket — a
fixed, small set no matter what lengths arrive.  A bucket NEVER
truncates: when a prompt outgrows the ladder, ``bucket_for`` extends to
the next chunk multiple instead of clipping (property-tested).
"""
from __future__ import annotations

from typing import Sequence, Tuple


def bucket_ladder(chunk_len: int, max_len: int) -> Tuple[int, ...]:
    """Default ladder: powers-of-two multiples of ``chunk_len`` through
    the first rung covering ``max_len``.  O(log(max_len / chunk_len))
    rungs, each a chunk multiple — the compile bound under open-world
    traffic."""
    if chunk_len < 1:
        raise ValueError("chunk_len must be >= 1")
    rungs = [chunk_len]
    while rungs[-1] < max_len:
        rungs.append(rungs[-1] * 2)
    return tuple(rungs)


def validate_ladder(ladder: Sequence[int], chunk_len: int) -> Tuple[int, ...]:
    """Sorted, deduplicated ladder; every rung must be a positive
    multiple of ``chunk_len`` (the admission scan runs rung/chunk_len
    chunks, so anything else would change the chunk shape)."""
    rungs = sorted(set(int(r) for r in ladder))
    if not rungs:
        raise ValueError("bucket ladder is empty")
    for r in rungs:
        if r < 1 or r % chunk_len:
            raise ValueError(
                f"bucket rung {r} is not a positive multiple of "
                f"chunk_len {chunk_len}")
    return tuple(rungs)


def bucket_for(length: int, ladder: Sequence[int], chunk_len: int) -> int:
    """Smallest rung >= ``length``; past the top rung, the next chunk
    multiple (never truncate — a bucket below the prompt length would
    silently drop tokens)."""
    if length < 0:
        raise ValueError("length must be >= 0")
    for r in ladder:
        if r >= length:
            return r
    return -(-max(length, 1) // chunk_len) * chunk_len
