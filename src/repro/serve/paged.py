"""Block allocator for the paged serve engine: free list, refcounts,
content-keyed prefix sharing.

One block id spans every paged cache leaf (all layers), mirroring
``models.model.init_paged_cache``.  Block 0 is the **trash block**: it
is never handed out, and the engine points finished slots' block tables
(and write positions) at it so their masked garbage decode writes land
somewhere sacrificial instead of corrupting reallocated blocks.

Prefix sharing is content-keyed, vLLM-style: a *full* block whose
positions lie entirely inside the prompt region has content determined
by (block index, modality digest, token prefix through the block's end).
``acquire`` returns the existing block (refcount + 1) when the key is
already pooled, so identical Phase II task preambles are stored once.
Blocks at or past the write frontier (the partial prompt tail block and
all decode blocks) are always ``alloc``'d privately — decode writes can
therefore never touch a shared block, which is what keeps diverged
suffixes from aliasing (copy-on-write resolved at admission time).
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

TRASH = 0  # pool row 0: absorbs dead slots' masked writes, never allocated


class PagedAllocator:
    """Free-list + refcount bookkeeping over ``n_blocks`` pool rows
    (ids 1..n_blocks-1; row 0 is the trash block).

    ``n_shards > 1`` matches a mesh-sharded pool
    (``sharding.rules.paged_cache_specs``): device d owns the contiguous
    id range [d * n_blocks/n_shards, (d+1) * n_blocks/n_shards), and the
    allocator keeps one free list per shard, handing new blocks out of
    the emptiest shard so live blocks — and therefore paged-attention
    read traffic — stay balanced across devices.  ``n_shards=1`` is the
    single-device allocator, id-for-id identical to before the split.
    """

    def __init__(self, n_blocks: int, block_len: int, n_shards: int = 1):
        if n_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the trash block)")
        if block_len < 1:
            raise ValueError("block_len must be >= 1")
        if n_shards < 1 or n_blocks % n_shards:
            raise ValueError(
                f"n_blocks {n_blocks} must divide into n_shards {n_shards}")
        self.n_blocks, self.block_len = n_blocks, block_len
        self.n_shards = n_shards
        self._per_shard = n_blocks // n_shards
        # per-shard free lists; pop() hands out each shard's low ids
        # first.  The trash block (id 0) sits in shard 0 and is skipped.
        self._free_by_shard: List[List[int]] = [
            list(range(min((d + 1) * self._per_shard - 1, n_blocks - 1),
                       max(d * self._per_shard - 1, 0), -1))
            for d in range(n_shards)]
        self.refcount = [0] * n_blocks
        self._key_of: Dict[int, Tuple] = {}
        self._bid_of: Dict[Tuple, int] = {}
        self.shared_hits = 0

    def shard_of(self, bid: int) -> int:
        return bid // self._per_shard

    # -- capacity ----------------------------------------------------------

    @property
    def n_free(self) -> int:
        return sum(len(f) for f in self._free_by_shard)

    def n_free_shard(self, shard: int) -> int:
        return len(self._free_by_shard[shard])

    def free_ids(self) -> List[int]:
        """Every free block id, across all shards (introspection)."""
        return [b for f in self._free_by_shard for b in f]

    @property
    def n_live(self) -> int:
        return (self.n_blocks - 1) - self.n_free

    def lookup(self, key) -> Optional[int]:
        """Block id pooled under ``key``, or None (refcount untouched)."""
        return self._bid_of.get(key)

    # -- alloc / share / free ----------------------------------------------

    def alloc(self) -> int:
        """A private (unkeyed, refcount-1) block, from the shard with the
        most free blocks (lowest shard index on ties — with one shard
        this degenerates to the original single free list)."""
        shard = max(range(self.n_shards),
                    key=lambda d: (len(self._free_by_shard[d]), -d))
        if not self._free_by_shard[shard]:
            raise RuntimeError("paged KV pool exhausted")
        bid = self._free_by_shard[shard].pop()
        self.refcount[bid] = 1
        return bid

    def acquire(self, key) -> Tuple[int, bool]:
        """Refcount the block pooled under ``key``, allocating (and
        keying) a fresh one on miss.  Returns (block_id, fresh) — the
        caller must write the block's content iff ``fresh``."""
        bid = self._bid_of.get(key)
        if bid is not None:
            self.refcount[bid] += 1
            self.shared_hits += 1
            return bid, False
        bid = self.alloc()
        self._bid_of[key] = bid
        self._key_of[bid] = key
        return bid, True

    def release(self, bid: int) -> None:
        """Drop one reference; a block returns to the free list (and its
        key leaves the content pool) exactly when its refcount hits 0."""
        if bid == TRASH:
            raise ValueError("cannot release the trash block")
        if not (0 < bid < self.n_blocks):
            raise ValueError(f"block id {bid} out of range")
        if self.refcount[bid] <= 0:
            raise ValueError(f"double free of block {bid}")
        self.refcount[bid] -= 1
        if self.refcount[bid] == 0:
            key = self._key_of.pop(bid, None)
            if key is not None:
                del self._bid_of[key]
            self._free_by_shard[self.shard_of(bid)].append(bid)


def prompt_digest(batch) -> bytes:
    """Digest of every non-token modality input (vlm patches, encdec
    frames).  KV content anywhere in the sequence depends on these (the
    frontend rows prefix the prompt; encdec cross-attends the frames),
    so prefix keys must include them."""
    extra = [np.asarray(v).tobytes()
             for k, v in sorted(batch.items()) if k != "tokens"]
    if not extra:
        return b""
    return hashlib.sha1(b"".join(extra)).digest()


def prefix_keys(batch, n_full_blocks: int, block_len: int, offset: int,
                policy: str = ""):
    """Content keys for the full blocks below the write frontier.

    Block ``i`` covers positions [i*bl, (i+1)*bl); with a modality
    frontend of ``offset`` rows, token positions map to
    ``tokens[p - offset]``, so block ``i``'s KV is a pure function of
    (modality inputs, tokens[: (i+1)*bl - offset]).  The block index is
    part of the key: frontend-only blocks of different depths share a
    (possibly empty) token prefix but hold different rows.

    ``policy`` is the cache's storage policy (``CachePolicy.kv_dtype``):
    block bytes written under different policies differ for the same
    tokens, so the policy salts the key — a quantized pool can never
    alias blocks written under a different dtype (e.g. a
    ``--check-unquantized`` replay sharing one allocator).

    Note: two prompts of *different total length* sharing a token prefix
    get the same keys — their shared-block KV is mathematically
    identical but computed by different prefill executables, so reuse
    across lengths is equal to float tolerance, not guaranteed
    bit-identical.  Same-length prompts (the Phase II preamble case)
    share bit-exactly.
    """
    toks = np.asarray(batch["tokens"][0])
    base = prompt_digest(batch)
    keys = []
    for i in range(n_full_blocks):
        n_tok = max((i + 1) * block_len - offset, 0)
        keys.append((i, base, toks[:n_tok].astype(np.int64).tobytes(),
                     policy))
    return keys
