"""Slot-based continuous-batching generation engine.

The engine serves a queue of variable-length requests through a fixed
set of ``n_slots`` batch rows:

  admit    : prefill a queued request at B=1, graft its cache into a
             free slot (``prefill_into_cache`` + a per-slot scatter),
             sample emission #1 from the prefill logits.
  segment  : ONE compiled ``lax.scan`` of ``seg_len`` decode steps over
             the whole batch (``models.model.generate``), per-slot
             position / remaining-length / EOS state carried through the
             scan.  Finished slots keep running as masked garbage until
             the segment ends — shapes never change, nothing recompiles.
  between  : finished slots are freed and refilled from the queue, so
             mixed-length traffic keeps the batch full instead of
             padding every request to the longest one.

Two cache layouts share that lifecycle:

``ServeEngine`` (contiguous) owns one ``(n_slots, max_len)`` decode
cache — engine capacity is ``n_slots * max_len`` rows no matter how
short requests are.  ``PagedServeEngine`` owns an ``(n_blocks,
block_len)`` block pool per attention leaf plus per-slot block tables
(``repro.serve.paged``): a request holds exactly the blocks its own
capacity spans, identical prompt prefixes are pooled once (refcounted,
copy-on-write resolved at admission), and slot count is bounded by live
tokens rather than ``n_slots * max_len``.

Slot independence: attention/SSM state and MoE routing never mix batch
rows — the decode scan threads a per-row liveness mask (``~done``) into
``decode_step``, so freed garbage lanes are zeroed out of router
probabilities AND excluded from expert-capacity ranking on every MoE
path, including the multi-device ``moe_a2a`` one (a freed slot can
never crowd a live token out of an expert; see
tests/test_serve_sharded.py).  A request's tokens are therefore
identical to a solo run with the same per-request PRNG key.

Sharded serving: pass ``mesh=`` and the engine lays its decode cache
(or block pools) out with ``NamedSharding`` per ``sharding.rules`` —
slots over the data axes, pool/feature dims over "model" — and every
compiled admit/segment executable runs sharded.  A 1-device mesh is
bit-identical to ``mesh=None``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models import quant
from repro.models.config import ModelConfig
from repro.serve import bucketing as bk
from repro.serve import paged as pg
from repro.serve.sampling import Greedy


@dataclasses.dataclass
class Request:
    """One generation request.  ``batch`` is a leading-dim-1 prefill
    batch (``tokens`` plus ``patches``/``frames`` for vlm/encdec);
    ``max_new`` counts ALL generated tokens, including the one sampled
    from the prefill logits."""
    uid: int
    batch: Dict[str, Any]
    max_new: int
    key: Optional[Any] = None  # per-request PRNG key (seeded from uid if None)
    # memoised prefix-block content keys (paged engine): hashing the
    # prompt/modality bytes is done once, not per blocked admission retry
    plan_keys: Optional[List] = None

    @property
    def prompt_len(self) -> int:
        return self.batch["tokens"].shape[1]


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: np.ndarray     # (n_generated,) — includes the EOS token if hit
    n_segments: int        # decode segments this request rode through


class CompiledLRU:
    """Bounded per-shape executable cache.

    Under open-world traffic every distinct prompt length compiles (and
    permanently pins) a fresh prefill/admit executable if cached in an
    unbounded ``lru_cache`` — evicting the per-length jitted callable
    here drops its executables with it.  ``builds`` counts every build
    (including rebuilds after eviction): the compile-thrash metric the
    bucketed-admission benchmark reports.
    """

    def __init__(self, build: Callable[[Any], Callable], maxsize: int = 32):
        self._build, self._maxsize = build, max(maxsize, 1)
        self._cache: OrderedDict = OrderedDict()
        self.builds = 0

    def __call__(self, key):
        fn = self._cache.get(key)
        if fn is None:
            fn = self._build(key)
            self.builds += 1
            self._cache[key] = fn
            if len(self._cache) > self._maxsize:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return fn

    def __len__(self) -> int:
        return len(self._cache)


def _scatter_slot_row(cache, sub, slot, bat, seq=None):
    """Write a B=1 cache subtree back into row ``slot`` of the batched
    cache along each leaf's batch axis (``bat`` from
    ``decode_cache_batch_axes``).  Leaves with a sequence axis in
    ``seq`` (paged pools) pass through unchanged — they were updated in
    place through the block tables."""
    if seq is None:
        seq = jax.tree.map(lambda _: -1, bat)

    def put(dst, src, bax, sax):
        if sax >= 0:
            return src
        idx = [slice(None)] * dst.ndim
        idx[bax] = slot
        return dst.at[tuple(idx)].set(
            jnp.take(src, 0, axis=bax).astype(dst.dtype))

    return jax.tree.map(put, cache, sub, bat, seq)


@functools.lru_cache(maxsize=8)
def _prefill_fn(cfg: ModelConfig, mesh):
    """Shared jitted prefill (benchmarks use it for the non-engine serving
    modes).  The engine itself compiles through its bounded per-length
    ``CompiledLRU`` instead, so sustained open-world traffic cannot pin
    an executable per prompt length."""
    return jax.jit(lambda p, b: M.prefill(p, cfg, b, mesh=mesh))


class ServeEngine:
    """Continuous-batching engine over a fixed ``(n_slots, max_len)``
    decode cache.  ``submit()`` requests, then ``run()`` (or ``step()``
    segment-by-segment for external admission control); drain finished
    requests with ``pop_completions()`` under sustained traffic.

    With ``chunk_len`` set, admission switches to **bucketed chunked
    prefill**: the padded input length is rounded up a bucket ladder
    (``buckets``, default powers-of-two chunk multiples) and the prompt
    runs through the shared decode body in ``chunk_len``-token chunks
    directly into the slot's cache row — no separate B=1 prefill graft,
    and the admission executable is keyed on the BUCKET, so open-world
    traffic compiles O(#buckets) executables instead of one per
    distinct prompt length.  Output is token-identical to the
    unbucketed engine (greedy ties aside; chunked and one-shot prefill
    agree to float epsilon, not bitwise).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 128, sampler=None, eos_id: Optional[int] = None,
                 seg_len: int = 8, mesh=None, seed: int = 0,
                 history_limit: int = 4096, compile_cache_size: int = 32,
                 chunk_len: Optional[int] = None, buckets=None,
                 speculate: int = 0, kv_dtype: str = ""):
        cfg.validate()
        if cfg.is_moe and not cfg.moe_dropless:
            # capacity drops are a training-time tradeoff; serving must
            # keep single-device semantics on any mesh, so expert
            # buffers are sized worst-case (no token ever dropped)
            cfg = cfg.replace(moe_dropless=True)
        self.speculate = int(speculate)
        if self.speculate and not (cfg.n_mtp and "mtp" in params):
            raise ValueError(
                "speculate requires an MTP head: cfg.n_mtp > 0 with "
                "params['mtp'] (dense/moe/vlm families)")
        self.params, self.cfg = params, cfg
        # cache storage policy: "" keeps the param dtype; int8/fp8 store
        # KV quantized with per-position scale leaves (repro.models.quant)
        self.kv_dtype = kv_dtype
        self.policy = quant.CachePolicy(kv_dtype)
        self.n_slots, self.max_len, self.seg_len = n_slots, max_len, seg_len
        self.sampler = sampler if sampler is not None else Greedy()
        self.eos_id, self.mesh = eos_id, mesh
        self._base_key = jax.random.PRNGKey(seed)
        self.chunk_len = chunk_len
        if chunk_len is not None:
            ladder = (bk.bucket_ladder(chunk_len, max_len)
                      if buckets is None else buckets)
            self.buckets = bk.validate_ladder(ladder, chunk_len)
        else:
            if buckets is not None:
                raise ValueError("buckets requires chunk_len")
            self.buckets = None
        # bounded per-shape executable caches (see CompiledLRU): keyed on
        # prompt length (unbucketed) or bucket rung (chunked admission)
        self._prefill_exec = CompiledLRU(self._build_prefill,
                                         compile_cache_size)
        self._admit_exec = CompiledLRU(self._build_admit, compile_cache_size)
        self._init_cache()
        # per-slot host state
        self.tok = np.zeros((n_slots,), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.rem = np.zeros((n_slots,), np.int32)
        # speculative-decode draft seed: final-normed hidden of the
        # position that emitted the slot's pending token.  Zeros at
        # admission — a cold first draft simply gets rejected.
        self.h_spec = np.zeros((n_slots, cfg.d_model), jnp.dtype(cfg.dtype))
        self.keys = np.array(jax.random.split(self._base_key, n_slots))
        self.slot_uid = np.full((n_slots,), -1, np.int64)
        self._slot_seq = np.zeros((n_slots,), np.int64)  # admission order
        self._admit_seq = 0
        self._live_req: Dict[int, Request] = {}  # uid -> Request while live
        self.queue: deque = deque()
        self._pending: set = set()  # queued uids — O(1) reuse check
        self.completions: Dict[int, Completion] = {}
        self.history: deque = deque(maxlen=history_limit)  # (seg, slot, uid)
        self.segment_idx = 0
        self.stats = {"generated_tokens": 0, "segments": 0, "prefills": 0,
                      "slot_steps": 0, "live_slot_steps": 0,
                      "spec_steps": 0, "spec_extra_tokens": 0,
                      "peak_live_requests": 0}
        self._out: Dict[int, list] = {}
        self._plen: Dict[int, int] = {}
        self._nseg: Dict[int, int] = {}
        self._uid_auto = 0

    @property
    def compiles_built(self) -> int:
        """Total prefill/admit executables built so far (rebuilds after
        LRU eviction included) — O(#buckets) under chunked admission,
        O(#distinct prompt lengths) without."""
        return self._prefill_exec.builds + self._admit_exec.builds

    # -- cache layout hooks (overridden by PagedServeEngine) ---------------

    def _init_cache(self) -> None:
        self.cache = M.init_decode_cache(self.cfg, self.n_slots, self.max_len,
                                         mesh=self.mesh, policy=self.policy)
        self._cache_shardings = self._shardings_of(self.cache)

    def _shardings_of(self, cache):
        """Per-leaf NamedShardings of the engine cache (None when
        single-device).  Captured once at init: cache donation makes
        every compiled segment/admit preserve this placement, and the
        admit builders re-constrain their outputs to it as insurance."""
        if self.mesh is None or self.mesh.size == 1:
            return None
        return jax.tree.map(lambda x: x.sharding, cache)

    def _constrain_cache(self, cache):
        if self._cache_shardings is None:
            return cache
        return jax.tree.map(jax.lax.with_sharding_constraint, cache,
                            self._cache_shardings)

    def _build_prefill(self, P: int):
        cfg, mesh, spec = self.cfg, self.mesh, bool(self.speculate)
        return jax.jit(lambda p, b: M.prefill(p, cfg, b, mesh=mesh,
                                              return_hidden=spec))

    def _build_admit(self, key):
        """Jitted admission, one dispatch, batched cache donated.

        Unbucketed (``key`` = prompt length): graft a B=1 prefill cache
        and scatter it into row ``slot`` of the engine's batched cache
        (batch axis per leaf from ``decode_cache_batch_axes``).

        Chunked (``key`` = bucket rung): slice the slot's B=1 cache
        view, run ``prefill_chunked`` through it, scatter the view back
        and return the last real token's logits — prompt length and
        slot are runtime operands, so every prompt in the bucket reuses
        this one executable."""
        if self.chunk_len is not None:
            return self._build_admit_chunked(key)
        cfg, max_len = self.cfg, self.max_len
        axes = M.decode_cache_batch_axes(cfg, policy=self.policy)

        def admit(cache, pc, slot):
            sub = M.prefill_into_cache(
                cfg, M.init_decode_cache(cfg, 1, max_len), pc)
            # quantized engines graft full-precision, then quantize the
            # whole slot row to the cache's policy (adds scale leaves)
            sub = M.match_cache_policy(cache, sub)
            return self._constrain_cache(_scatter_slot_row(cache, sub, slot,
                                                           axes))

        return jax.jit(admit, donate_argnums=(0,))

    def _build_admit_chunked(self, rung: int):
        cfg, mesh, C = self.cfg, self.mesh, self.chunk_len
        axes = M.decode_cache_batch_axes(cfg, policy=self.policy)

        def admit(params, cache, batch, prompt_len, slot):
            s1 = jnp.reshape(slot, (1,))
            sub = jax.tree.map(
                lambda leaf, ax: jnp.take(leaf, s1, axis=ax), cache, axes)
            logits, sub = M.prefill_chunked(params, cfg, sub, batch,
                                            prompt_len, chunk_len=C,
                                            mesh=mesh)
            cache = self._constrain_cache(
                _scatter_slot_row(cache, sub, slot, axes))
            return logits, cache

        return jax.jit(admit, donate_argnums=(1,))

    # -- request intake ----------------------------------------------------

    def submit(self, batch, *, max_new: int, uid: Optional[int] = None,
               key=None) -> int:
        if uid is None:
            uid = self._uid_auto
            self._uid_auto += 1
        else:
            self._uid_auto = max(self._uid_auto, uid + 1)
        if uid in self.completions or uid in self._out or uid in self._pending:
            raise ValueError(f"request uid {uid} already in use")
        bad = [k for k, v in batch.items() if v.shape[0] != 1]
        if bad:
            raise ValueError(
                f"request {uid}: batch entries {bad} must have leading dim 1 "
                f"(one request per submit)")
        self._validate_capacity(uid, batch["tokens"].shape[1], max_new)
        if max_new < 1:
            raise ValueError(f"request {uid}: max_new must be >= 1")
        self.queue.append(Request(uid, batch, max_new, key))
        self._pending.add(uid)
        return uid

    def _validate_capacity(self, uid: int, P: int, max_new: int) -> None:
        need = M.decode_capacity(self.cfg, P, max_new)
        if need > self.max_len:
            raise ValueError(
                f"request {uid}: prompt {P} + max_new {max_new} needs cache "
                f"capacity {need} > engine max_len {self.max_len}")

    @property
    def idle(self) -> bool:
        return not self.queue and not (self.slot_uid >= 0).any()

    def pop_completions(self) -> Dict[int, Completion]:
        """Drain finished requests — the bound on ``completions`` growth
        under sustained traffic (their uids become reusable)."""
        out, self.completions = self.completions, {}
        return out

    # -- admission ---------------------------------------------------------

    def _finish(self, uid: int) -> None:
        self._live_req.pop(uid, None)
        self.completions[uid] = Completion(
            uid, self._plen.pop(uid),
            np.asarray(self._out.pop(uid), np.int32), self._nseg.pop(uid))

    def _bucket_rung(self, P: int) -> int:
        """Bucket for a P-token prompt: the padded INPUT length
        (modality frontend + tokens) rounded up the ladder."""
        return bk.bucket_for(M.decode_pos0(self.cfg, P), self.buckets,
                             self.chunk_len)

    def _padded_batch(self, req: Request, rung: int):
        """The request's batch with tokens right-padded so the full
        input sequence is exactly ``rung`` long (pad values are masked
        out of cache/state by the chunked prefill contract)."""
        T_pad = rung - M.decode_offset(self.cfg)
        toks = np.zeros((1, T_pad), np.int32)
        toks[:, :req.prompt_len] = np.asarray(req.batch["tokens"])
        batch = dict(req.batch)
        batch["tokens"] = jnp.asarray(toks)
        return batch

    def _plan(self, req: Request):
        """Admission plan (bucket rung; paged adds block keys/counts).
        None = nothing to plan (unbucketed contiguous admission)."""
        if self.chunk_len is None:
            return None
        return {"rung": self._bucket_rung(req.prompt_len)}

    def _fits(self, plan) -> bool:
        """Can the planned request be placed right now?"""
        return True

    def _place(self, slot: int, req: Request, pc, plan) -> None:
        self.cache = self._admit_exec(req.prompt_len)(self.cache, pc, slot)

    def _admit_chunked_into(self, slot: int, req: Request, plan):
        """Run the bucketed chunked prefill straight into ``slot``'s
        cache row; returns the last real token's logits (1, V)."""
        rung = plan["rung"]
        logits, self.cache = self._admit_exec(rung)(
            self.params, self.cache, self._padded_batch(req, rung),
            jnp.int32(req.prompt_len), jnp.int32(slot))
        return logits

    def _rollback_place(self, slot: int, req: Request) -> None:
        """Undo a chunked placement whose request finished at prefill
        (max_new == 1 / instant EOS): the slot was never marked live, so
        only layout resources (paged blocks) need returning."""

    def _release_slot(self, slot: int) -> None:
        self.slot_uid[slot] = -1
        # EOS can finish a slot with budget left: zero it so the freed
        # lane runs masked (done = rem<=0) until re-admitted
        self.rem[slot] = 0
        self.h_spec[slot] = 0

    def _admit(self) -> None:
        free = [s for s in range(self.n_slots) if self.slot_uid[s] < 0]
        while free and self.queue:
            req = self.queue[0]
            plan = self._plan(req)
            if not self._fits(plan):
                break  # blocked on pool space: keep arrival order
            self.queue.popleft()
            self._pending.discard(req.uid)
            key = req.key if req.key is not None else \
                jax.random.fold_in(self._base_key, req.uid)
            key, k0 = jax.random.split(key)
            if self.chunk_len is None:
                # unbucketed: slotless B=1 prefill, graft deferred so a
                # request finishing at prefill never touches the cache
                slot = free[0]
                logits, pc = self._prefill_exec(req.prompt_len)(self.params,
                                                                req.batch)
                if self.speculate:
                    logits, h0 = logits  # return_hidden packs (logits, h)
            else:
                # bucketed: the chunked prefill IS the placement — it
                # writes through the slot's cache row / block tables
                slot = free[0]
                logits = self._admit_chunked_into(slot, req, plan)
            e0 = int(np.asarray(self.sampler(k0[None], logits))[0])
            self._out[req.uid] = [e0]
            self._plen[req.uid] = req.prompt_len
            self._nseg[req.uid] = 0
            self.stats["prefills"] += 1
            self.stats["generated_tokens"] += 1
            if req.max_new <= 1 or (self.eos_id is not None
                                    and e0 == self.eos_id):
                self._finish(req.uid)  # done at prefill: no slot consumed
                if self.chunk_len is not None:
                    self._rollback_place(slot, req)
                continue
            free.pop(0)
            if self.chunk_len is None:
                self._place(slot, req, pc, plan)
            self.slot_uid[slot] = req.uid
            self._slot_seq[slot] = self._admit_seq
            self._admit_seq += 1
            self._live_req[req.uid] = req
            self.tok[slot] = e0
            self.pos[slot] = M.decode_pos0(self.cfg, req.prompt_len)
            self.rem[slot] = req.max_new - 1
            if self.speculate and self.chunk_len is None:
                # seed the draft chain with the prefill's last hidden —
                # the hidden of the position that emitted e0 — so the
                # slot's first step drafts hot.  Purely a speed win:
                # draft quality never changes accepted tokens.  Chunked
                # admission stays cold (first drafts simply rejected).
                self.h_spec[slot] = np.asarray(h0[0])
            else:
                self.h_spec[slot] = 0
            self.keys[slot] = np.asarray(key)
        self.stats["peak_live_requests"] = max(
            self.stats["peak_live_requests"], int((self.slot_uid >= 0).sum()))

    # -- scanned decode segment --------------------------------------------

    def _spec_kw(self) -> dict:
        if not self.speculate:
            return {}
        return {"speculate": self.speculate,
                "spec_h": jnp.asarray(self.h_spec)}

    def spec_acceptance(self) -> float:
        """Fraction of the k draft lanes per live step that yielded an
        accepted token (0.0 when not speculating or nothing ran)."""
        denom = self.stats["spec_steps"] * self.speculate
        return self.stats["spec_extra_tokens"] / denom if denom else 0.0

    def _run_segment(self):
        return M.generate(self.params, self.cfg, self.cache,
                          jnp.asarray(self.tok), jnp.asarray(self.pos),
                          steps=self.seg_len, sampler=self.sampler,
                          rng=jnp.asarray(self.keys), eos_id=self.eos_id,
                          remaining=jnp.asarray(self.rem), mesh=self.mesh,
                          **self._spec_kw())

    def _segment(self) -> None:
        res = self._run_segment()
        self.cache = res["cache"]
        if self._cache_shardings is not None:
            # the scanned segment's output shardings are the compiler's
            # choice; re-pin the engine layout (no-op when unchanged)
            self.cache = jax.tree.map(jax.device_put, self.cache,
                                      self._cache_shardings)
        toks, valid = np.asarray(res["tokens"]), np.asarray(res["valid"])
        done = np.asarray(res["done"])
        # writable copies — _admit() mutates these per slot
        self.tok = np.array(res["next_tok"])
        self.pos = np.array(res["pos"])
        self.rem = np.array(res["remaining"])
        self.keys = np.array(res["rng"])
        if self.speculate:
            self.h_spec = np.array(res["h_spec"])
            # a live slot always emits at column i*(k+1) of step i, so
            # those columns count the slot's live steps; every further
            # True column is a token the draft+verify chain got for free
            first = valid[:, ::self.speculate + 1]
            self.stats["spec_steps"] += int(first.sum())
            self.stats["spec_extra_tokens"] += int(valid.sum() - first.sum())
        for s in range(self.n_slots):
            uid = int(self.slot_uid[s])
            if uid < 0:
                continue
            self.history.append((self.segment_idx, s, uid))
            new = toks[s][valid[s]].tolist()
            self._out[uid].extend(new)
            self._nseg[uid] += 1
            self.stats["generated_tokens"] += len(new)
            self.stats["live_slot_steps"] += len(new)
            if done[s]:
                self._finish(uid)
                self._release_slot(s)
        # capacity per segment is seg_len emissions per slot, times the
        # chunk width when speculating (each step can emit up to k+1)
        self.stats["slot_steps"] += (self.n_slots * self.seg_len
                                     * (self.speculate + 1))
        self.stats["segments"] += 1
        self.segment_idx += 1

    # -- driving -----------------------------------------------------------

    def _pre_segment(self) -> None:
        """Hook between admission and the decode segment (paged lazy
        block extension / preemption)."""

    def step(self) -> None:
        """Admit waiting requests, then run one decode segment."""
        self._admit()
        self._pre_segment()
        if (self.slot_uid >= 0).any():
            self._segment()

    def run(self) -> Dict[int, Completion]:
        """Drain the queue: segments with admission in between."""
        t0 = time.perf_counter()
        while not self.idle:
            self.step()
        self.stats["wall_s"] = (self.stats.get("wall_s", 0.0)
                                + time.perf_counter() - t0)
        return self.completions


class PagedServeEngine(ServeEngine):
    """Continuous batching over a block-paged KV cache.

    A request is admitted holding blocks from the shared pool, full
    prompt blocks dedup'd against the allocator's content pool, so
    concurrency is bounded by *live tokens* (plus per-request round-up)
    instead of ``n_slots * max_len``.

    With ``lazy=True`` (default) admission claims only the blocks the
    PROMPT spans; decode blocks are claimed per segment as the write
    frontier crosses block boundaries (``_pre_segment``), so a request
    holds memory proportional to what it has actually generated —
    long-``max_new`` traffic no longer reserves its worst case up
    front.  If the pool runs dry between segments the youngest-admitted
    live request is preempted: its blocks return to the pool and the
    request re-queues for a deterministic replay (same per-request key,
    so its final tokens are unchanged).  The oldest request is never
    preempted, which guarantees forward progress.  ``lazy=False``
    restores the PR 4 behavior: ``ceil(decode_capacity / block_len)``
    blocks at admission, tables fixed for the request's lifetime.
    """

    def __init__(self, params, cfg: ModelConfig, *, block_len: int = 16,
                 n_blocks: Optional[int] = None, n_slots: int = 4,
                 max_len: int = 128, share_prefix: bool = True,
                 lazy: bool = True, **kw):
        self.block_len = block_len
        self.max_blocks = -(-max_len // block_len)
        # speculative verify chunks write up to k positions past the
        # accepted frontier; a full-capacity slot would overflow its last
        # real table column (gathers CLAMP, aliasing the final block), so
        # the table gets spare always-TRASH columns to absorb overshoot
        spec = int(kw.get("speculate", 0) or 0)
        self._spec_spare = -(-spec // block_len) if spec else 0
        # default pool: worst case every slot holds max_len live tokens
        self.n_blocks = (1 + n_slots * self.max_blocks
                         if n_blocks is None else n_blocks)
        self._has_paged = M.has_paged_leaves(cfg)
        self.share_prefix = share_prefix and self._has_paged
        self.lazy = lazy and self._has_paged
        # per-shard free lists mirror the pool sharding: each device owns
        # a contiguous run of block ids (rules.paged_cache_specs), so the
        # allocator can keep every shard's block population balanced
        mesh = kw.get("mesh")
        n_shards = 1
        if mesh is not None and mesh.size > 1:
            n_data = 1
            for a in ("pod", "data"):
                if a in mesh.axis_names:
                    n_data *= mesh.shape[a]
            if n_data > 1 and self.n_blocks % n_data == 0:
                n_shards = n_data
        self.alloc = pg.PagedAllocator(self.n_blocks, block_len,
                                       n_shards=n_shards)
        self._table_w = self.max_blocks + self._spec_spare
        self.block_tables = np.full((n_slots, self._table_w), pg.TRASH,
                                    np.int32)
        self._slot_blocks: Dict[int, List[int]] = {}  # uid -> held block ids
        super().__init__(params, cfg, n_slots=n_slots, max_len=max_len, **kw)
        self.stats.update({"shared_blocks": 0, "fresh_blocks": 0,
                           "peak_live_blocks": 0, "lazy_claimed_blocks": 0,
                           "preemptions": 0})

    # -- cache layout ------------------------------------------------------

    def _init_cache(self) -> None:
        self.cache = M.init_paged_cache(self.cfg, self.n_slots, self.n_blocks,
                                        self.block_len, mesh=self.mesh,
                                        policy=self.policy)
        self._cache_shardings = self._shardings_of(self.cache)

    def _build_admit(self, key):
        if self.chunk_len is not None:
            return self._build_admit_chunked(key)
        cfg, bl = self.cfg, self.block_len
        n_pb = -(-M.decode_pos0(cfg, key) // bl)  # blocks holding prompt rows

        def admit(cache, pc, slot, ids, mask):
            sub = M.prefill_into_cache(
                cfg, M.init_decode_cache(cfg, 1, n_pb * bl), pc)
            return self._constrain_cache(
                M.scatter_prefill_paged(cfg, cache, sub, slot, ids, mask,
                                        block_len=bl))

        return jax.jit(admit, donate_argnums=(0,))

    def _build_admit_chunked(self, rung: int):
        """Chunked admission against the paged layout: slot-resident
        leaves are sliced to a B=1 view, pool leaves pass through whole
        and the chunk writes flow through the (rung-wide) read/write
        tables — the write table diverts already-pooled shared prefix
        rows to the trash block so chunked re-computation can never
        perturb content other requests are reading."""
        cfg, mesh, C = self.cfg, self.mesh, self.chunk_len
        bat = M.decode_cache_batch_axes(cfg, policy=self.policy)
        seq = M.decode_cache_seq_axes(cfg, policy=self.policy)

        def admit(params, cache, batch, prompt_len, slot, read_tbl,
                  write_tbl):
            s1 = jnp.reshape(slot, (1,))
            sub = jax.tree.map(
                lambda leaf, bax, sax: leaf if sax >= 0 else
                jnp.take(leaf, s1, axis=bax),
                cache, bat, seq)
            logits, sub = M.prefill_chunked(params, cfg, sub, batch,
                                            prompt_len, chunk_len=C,
                                            mesh=mesh, block_tables=read_tbl,
                                            write_tables=write_tbl)
            cache = self._constrain_cache(
                _scatter_slot_row(cache, sub, slot, bat, seq))
            return logits, cache

        return jax.jit(admit, donate_argnums=(1,))

    # -- admission ---------------------------------------------------------

    def _validate_capacity(self, uid: int, P: int, max_new: int) -> None:
        super()._validate_capacity(uid, P, max_new)
        if not self._has_paged:
            return
        n_total = -(-M.decode_capacity(self.cfg, P, max_new)
                    // self.block_len)
        if n_total > self.n_blocks - 1:
            # admission could otherwise stall forever waiting for blocks
            # the pool can never provide, even with every slot free
            raise ValueError(
                f"request {uid}: needs {n_total} blocks > pool of "
                f"{self.n_blocks - 1} allocatable blocks")

    def _n_total_blocks(self, req: Request) -> int:
        return -(-M.decode_capacity(self.cfg, req.prompt_len, req.max_new)
                 // self.block_len)

    def _plan(self, req: Request):
        rung = (self._bucket_rung(req.prompt_len)
                if self.chunk_len is not None else None)
        if not self._has_paged:
            return {"rung": rung, "keys": [], "n_pb": 0, "n_alloc": 0,
                    "missing": 0}
        bl = self.block_len
        pos0 = M.decode_pos0(self.cfg, req.prompt_len)
        n_total = self._n_total_blocks(req)
        n_pb = -(-pos0 // bl)
        if req.plan_keys is None:
            req.plan_keys = (pg.prefix_keys(req.batch, pos0 // bl, bl,
                                            M.decode_offset(self.cfg),
                                            policy=self.kv_dtype)
                             if self.share_prefix else [])
        keys = req.plan_keys
        # lazy admission claims only the prompt's blocks; the rest are
        # claimed per segment as the write frontier crosses boundaries
        n_alloc = n_pb if self.lazy else n_total
        # the lookup part IS re-evaluated per attempt: pool contents
        # change between segments while the request waits for blocks
        missing = n_alloc - sum(1 for k in keys
                                if self.alloc.lookup(k) is not None)
        return {"rung": rung, "keys": keys, "n_pb": n_pb, "n_alloc": n_alloc,
                "missing": missing}

    def _fits(self, plan) -> bool:
        return plan["missing"] <= self.alloc.n_free

    def _acquire_blocks(self, uid: int, plan):
        """Claim the plan's blocks: shared ``acquire`` for full prompt
        blocks, private ``alloc`` from the partial tail onward (decode
        writes and diverged suffixes must never alias).  Returns
        (ids, fresh) — ``fresh[i]`` False iff block i was pooled."""
        keys = plan["keys"]
        ids, fresh = [], []
        for i in range(plan["n_alloc"]):
            if i < len(keys):
                bid, fr = self.alloc.acquire(keys[i])
                self.stats["shared_blocks" if not fr
                           else "fresh_blocks"] += 1
            else:
                bid, fr = self.alloc.alloc(), True
                self.stats["fresh_blocks"] += 1
            ids.append(bid)
            fresh.append(fr)
        self._slot_blocks[uid] = ids
        self.stats["peak_live_blocks"] = max(self.stats["peak_live_blocks"],
                                             self.alloc.n_live)
        return ids, fresh

    def _set_table_row(self, slot: int, ids) -> None:
        # ids never exceed max_blocks, so the _spec_spare tail columns
        # stay TRASH for the slot's whole lifetime: speculative writes
        # past capacity are diverted, never aliased onto a real block
        row = np.full((self._table_w,), pg.TRASH, np.int32)
        row[:len(ids)] = ids
        self.block_tables[slot] = row

    def _place(self, slot: int, req: Request, pc, plan) -> None:
        ids, fresh = self._acquire_blocks(req.uid, plan)
        n_pb = plan["n_pb"]
        self._set_table_row(slot, ids)
        self.cache = self._admit_exec(req.prompt_len)(
            self.cache, pc, slot, jnp.asarray(ids[:n_pb], jnp.int32),
            jnp.asarray(fresh[:n_pb], jnp.bool_))

    def _admit_chunked_into(self, slot: int, req: Request, plan):
        rung, bl = plan["rung"], self.block_len
        W = -(-rung // bl)  # wide enough for every padded position
        read = np.full((1, W), pg.TRASH, np.int32)
        write = np.full((1, W), pg.TRASH, np.int32)
        if self._has_paged:
            ids, fresh = self._acquire_blocks(req.uid, plan)
            # admission tables carry the PROMPT blocks only (n_pb <= W
            # since pos0 <= rung): chunk writes never touch decode
            # blocks — pads beyond the prompt land in the trash block —
            # so eager mode's extra n_total - n_pb blocks stay out of
            # the (rung-keyed, fixed-width) admission operands and only
            # enter the segment tables below
            n_pb = plan["n_pb"]
            read[0, :n_pb] = ids[:n_pb]
            write[0, :n_pb] = [bid if fr else pg.TRASH
                               for bid, fr in zip(ids[:n_pb], fresh[:n_pb])]
            self._set_table_row(slot, ids)
        logits, self.cache = self._admit_exec(rung)(
            self.params, self.cache, self._padded_batch(req, rung),
            jnp.int32(req.prompt_len), jnp.int32(slot),
            jnp.asarray(read), jnp.asarray(write))
        return logits

    def _rollback_place(self, slot: int, req: Request) -> None:
        for bid in self._slot_blocks.pop(req.uid, []):
            self.alloc.release(bid)
        self.block_tables[slot] = pg.TRASH
        self.pos[slot] = 0

    def _release_slot(self, slot: int) -> None:
        uid = int(self.slot_uid[slot])
        super()._release_slot(slot)
        for bid in self._slot_blocks.pop(uid, []):
            self.alloc.release(bid)
        # dead lane: writes pin to (trash block, offset 0) until re-admitted
        self.block_tables[slot] = pg.TRASH
        self.pos[slot] = 0

    # -- lazy per-segment block claiming + preemption ----------------------

    def _segment_needs(self) -> Dict[int, int]:
        """slot -> blocks to claim so the coming segment's writes stay
        inside the slot's table (frontier can advance min(seg_len, rem)
        positions; capacity-capped)."""
        bl, needs = self.block_len, {}
        for s in range(self.n_slots):
            uid = int(self.slot_uid[s])
            if uid < 0:
                continue
            adv = int(min(self.seg_len * (self.speculate + 1), self.rem[s]))
            if adv <= 0:
                continue
            # + speculate: the step that lands the last accepted token
            # also wrote its rejected draft tail past the frontier
            last_write = int(self.pos[s]) + adv - 1 + self.speculate
            n_total = self._n_total_blocks(self._live_req[uid])
            need = min(last_write // bl + 1, n_total)
            have = len(self._slot_blocks[uid])
            if need > have:
                needs[s] = need - have
        return needs

    def _preempt_youngest(self) -> None:
        """Return the youngest-admitted live request to the queue (its
        blocks go back to the pool; replay is deterministic, so its
        final tokens are unaffected)."""
        live = [s for s in range(self.n_slots) if self.slot_uid[s] >= 0]
        if len(live) <= 1:
            # unreachable: submit() rejects requests larger than the pool
            raise RuntimeError("paged pool exhausted by a single request")
        s = max(live, key=lambda s: self._slot_seq[s])
        uid = int(self.slot_uid[s])
        req = self._live_req.pop(uid)
        # roll back the discarded work so token/utilization stats only
        # count emissions that reach a completion (emission #1 came from
        # the prefill, not a slot step)
        discarded = self._out.pop(uid)
        self.stats["generated_tokens"] -= len(discarded)
        self.stats["live_slot_steps"] -= len(discarded) - 1
        self._plen.pop(uid)
        self._nseg.pop(uid)
        self.slot_uid[s] = -1
        self.rem[s] = 0
        self._rollback_place(s, req)
        self.queue.appendleft(req)  # admitted before anything still queued
        self._pending.add(uid)
        self.stats["preemptions"] += 1

    def _pre_segment(self) -> None:
        if not self._has_paged:
            return
        needs = self._segment_needs()
        while sum(needs.values()) > self.alloc.n_free:
            self._preempt_youngest()
            needs = self._segment_needs()
        for s, n in needs.items():
            ids = self._slot_blocks[int(self.slot_uid[s])]
            for _ in range(n):
                bid = self.alloc.alloc()
                self.block_tables[s, len(ids)] = bid
                ids.append(bid)
            self.stats["lazy_claimed_blocks"] += n
            self.stats["fresh_blocks"] += n
        if needs:
            self.stats["peak_live_blocks"] = max(
                self.stats["peak_live_blocks"], self.alloc.n_live)

    # -- scanned decode segment --------------------------------------------

    def _run_segment(self):
        return M.generate(self.params, self.cfg, self.cache,
                          jnp.asarray(self.tok), jnp.asarray(self.pos),
                          steps=self.seg_len, sampler=self.sampler,
                          rng=jnp.asarray(self.keys), eos_id=self.eos_id,
                          remaining=jnp.asarray(self.rem), mesh=self.mesh,
                          block_tables=jnp.asarray(self.block_tables),
                          **self._spec_kw())
