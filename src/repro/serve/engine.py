"""Slot-based continuous-batching generation engine.

The engine owns ONE fixed-shape decode cache of ``n_slots`` batch rows
and ``max_len`` positions and serves a queue of variable-length requests
through it:

  admit    : prefill a queued request at B=1, graft its cache into a
             free slot (``prefill_into_cache`` + a per-slot scatter),
             sample emission #1 from the prefill logits.
  segment  : ONE compiled ``lax.scan`` of ``seg_len`` decode steps over
             the whole batch (``models.model.generate``), per-slot
             position / remaining-length / EOS state carried through the
             scan.  Finished slots keep running as masked garbage until
             the segment ends — shapes never change, nothing recompiles.
  between  : finished slots are freed and refilled from the queue, so
             mixed-length traffic keeps the batch full instead of
             padding every request to the longest one.

Slot independence: attention/SSM state and (single-device) MoE routing
never mix batch rows, so a request's tokens are identical to a solo run
with the same per-request PRNG key (tests/test_serve_engine.py asserts
this).  Caveat: the multi-device ``moe_a2a`` path computes expert
capacity over ALL batch rows, so freed garbage lanes could crowd live
tokens out of an expert there — sharded decode is a ROADMAP follow-on
and needs live-token-masked routing first.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serve.sampling import Greedy


@dataclasses.dataclass
class Request:
    """One generation request.  ``batch`` is a leading-dim-1 prefill
    batch (``tokens`` plus ``patches``/``frames`` for vlm/encdec);
    ``max_new`` counts ALL generated tokens, including the one sampled
    from the prefill logits."""
    uid: int
    batch: Dict[str, Any]
    max_new: int
    key: Optional[Any] = None  # per-request PRNG key (seeded from uid if None)

    @property
    def prompt_len(self) -> int:
        return self.batch["tokens"].shape[1]


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: np.ndarray     # (n_generated,) — includes the EOS token if hit
    n_segments: int        # decode segments this request rode through


@functools.lru_cache(maxsize=None)
def _prefill_fn(cfg: ModelConfig, mesh):
    return jax.jit(lambda p, b: M.prefill(p, cfg, b, mesh=mesh))


@functools.lru_cache(maxsize=None)
def _admit_fn(cfg: ModelConfig, max_len: int):
    """Jitted admission: graft a B=1 prefill cache and scatter it into
    row ``slot`` of the engine's batched cache, fused into ONE dispatch
    (batch axis per leaf from ``decode_cache_batch_axes``; the batched
    cache is donated).  Recompiles per prompt shape, like prefill."""
    axes = M.decode_cache_batch_axes(cfg)

    def admit(cache, pc, slot):
        sub = M.prefill_into_cache(
            cfg, M.init_decode_cache(cfg, 1, max_len), pc)

        def put(dst, src, ax):
            idx = [slice(None)] * dst.ndim
            idx[ax] = slot
            return dst.at[tuple(idx)].set(
                jnp.take(src, 0, axis=ax).astype(dst.dtype))

        return jax.tree.map(put, cache, sub, axes)

    return jax.jit(admit, donate_argnums=(0,))


class ServeEngine:
    """Continuous-batching engine over a fixed ``(n_slots, max_len)``
    decode cache.  ``submit()`` requests, then ``run()`` (or ``step()``
    segment-by-segment for external admission control)."""

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 128, sampler=None, eos_id: Optional[int] = None,
                 seg_len: int = 8, mesh=None, seed: int = 0):
        cfg.validate()
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len, self.seg_len = n_slots, max_len, seg_len
        self.sampler = sampler if sampler is not None else Greedy()
        self.eos_id, self.mesh = eos_id, mesh
        self.cache = M.init_decode_cache(cfg, n_slots, max_len)
        self._base_key = jax.random.PRNGKey(seed)
        # per-slot host state
        self.tok = np.zeros((n_slots,), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.rem = np.zeros((n_slots,), np.int32)
        self.keys = np.array(jax.random.split(self._base_key, n_slots))
        self.slot_uid = np.full((n_slots,), -1, np.int64)
        self.queue: deque = deque()
        self.completions: Dict[int, Completion] = {}
        self.history: List[Tuple[int, int, int]] = []  # (segment, slot, uid)
        self.segment_idx = 0
        self.stats = {"generated_tokens": 0, "segments": 0, "prefills": 0,
                      "slot_steps": 0, "live_slot_steps": 0}
        self._out: Dict[int, list] = {}
        self._plen: Dict[int, int] = {}
        self._nseg: Dict[int, int] = {}
        self._uid_auto = 0

    # -- request intake ----------------------------------------------------

    def submit(self, batch, *, max_new: int, uid: Optional[int] = None,
               key=None) -> int:
        if uid is None:
            uid = self._uid_auto
            self._uid_auto += 1
        else:
            self._uid_auto = max(self._uid_auto, uid + 1)
        if uid in self.completions or uid in self._out or \
                any(r.uid == uid for r in self.queue):
            raise ValueError(f"request uid {uid} already in use")
        bad = [k for k, v in batch.items() if v.shape[0] != 1]
        if bad:
            raise ValueError(
                f"request {uid}: batch entries {bad} must have leading dim 1 "
                f"(one request per submit)")
        P = batch["tokens"].shape[1]
        need = M.decode_capacity(self.cfg, P, max_new)
        if need > self.max_len:
            raise ValueError(
                f"request {uid}: prompt {P} + max_new {max_new} needs cache "
                f"capacity {need} > engine max_len {self.max_len}")
        if max_new < 1:
            raise ValueError(f"request {uid}: max_new must be >= 1")
        self.queue.append(Request(uid, batch, max_new, key))
        return uid

    @property
    def idle(self) -> bool:
        return not self.queue and not (self.slot_uid >= 0).any()

    # -- admission ---------------------------------------------------------

    def _finish(self, uid: int) -> None:
        self.completions[uid] = Completion(
            uid, self._plen.pop(uid),
            np.asarray(self._out.pop(uid), np.int32), self._nseg.pop(uid))

    def _admit(self) -> None:
        free = [s for s in range(self.n_slots) if self.slot_uid[s] < 0]
        while free and self.queue:
            req = self.queue.popleft()
            logits, pc = _prefill_fn(self.cfg, self.mesh)(self.params,
                                                          req.batch)
            key = req.key if req.key is not None else \
                jax.random.fold_in(self._base_key, req.uid)
            key, k0 = jax.random.split(key)
            e0 = int(np.asarray(self.sampler(k0[None], logits))[0])
            self._out[req.uid] = [e0]
            self._plen[req.uid] = req.prompt_len
            self._nseg[req.uid] = 0
            self.stats["prefills"] += 1
            self.stats["generated_tokens"] += 1
            if req.max_new <= 1 or (self.eos_id is not None
                                    and e0 == self.eos_id):
                self._finish(req.uid)  # done at prefill: no slot needed,
                continue               # skip the cache graft entirely
            slot = free.pop(0)
            self.cache = _admit_fn(self.cfg, self.max_len)(self.cache, pc,
                                                           slot)
            self.slot_uid[slot] = req.uid
            self.tok[slot] = e0
            self.pos[slot] = M.decode_pos0(self.cfg, req.prompt_len)
            self.rem[slot] = req.max_new - 1
            self.keys[slot] = np.asarray(key)

    # -- scanned decode segment --------------------------------------------

    def _segment(self) -> None:
        res = M.generate(self.params, self.cfg, self.cache,
                         jnp.asarray(self.tok), jnp.asarray(self.pos),
                         steps=self.seg_len, sampler=self.sampler,
                         rng=jnp.asarray(self.keys), eos_id=self.eos_id,
                         remaining=jnp.asarray(self.rem), mesh=self.mesh)
        self.cache = res["cache"]
        toks, valid = np.asarray(res["tokens"]), np.asarray(res["valid"])
        done = np.asarray(res["done"])
        # writable copies — _admit() mutates these per slot
        self.tok = np.array(res["next_tok"])
        self.pos = np.array(res["pos"])
        self.rem = np.array(res["remaining"])
        self.keys = np.array(res["rng"])
        for s in range(self.n_slots):
            uid = int(self.slot_uid[s])
            if uid < 0:
                continue
            self.history.append((self.segment_idx, s, uid))
            new = toks[s][valid[s]].tolist()
            self._out[uid].extend(new)
            self._nseg[uid] += 1
            self.stats["generated_tokens"] += len(new)
            self.stats["live_slot_steps"] += len(new)
            if done[s]:
                self._finish(uid)
                self.slot_uid[s] = -1
                # EOS can finish a slot with budget left: zero it so the
                # freed lane runs masked (done = rem<=0) until re-admitted
                self.rem[s] = 0
        self.stats["slot_steps"] += self.n_slots * self.seg_len
        self.stats["segments"] += 1
        self.segment_idx += 1

    # -- driving -----------------------------------------------------------

    def step(self) -> None:
        """Admit waiting requests, then run one decode segment."""
        self._admit()
        if (self.slot_uid >= 0).any():
            self._segment()

    def run(self) -> Dict[int, Completion]:
        """Drain the queue: segments with admission in between."""
        t0 = time.perf_counter()
        while not self.idle:
            self.step()
        self.stats["wall_s"] = (self.stats.get("wall_s", 0.0)
                                + time.perf_counter() - t0)
        return self.completions
