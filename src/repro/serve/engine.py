"""Slot-based continuous-batching generation engine.

The engine serves a queue of variable-length requests through a fixed
set of ``n_slots`` batch rows:

  admit    : prefill a queued request at B=1, graft its cache into a
             free slot (``prefill_into_cache`` + a per-slot scatter),
             sample emission #1 from the prefill logits.
  segment  : ONE compiled ``lax.scan`` of ``seg_len`` decode steps over
             the whole batch (``models.model.generate``), per-slot
             position / remaining-length / EOS state carried through the
             scan.  Finished slots keep running as masked garbage until
             the segment ends — shapes never change, nothing recompiles.
  between  : finished slots are freed and refilled from the queue, so
             mixed-length traffic keeps the batch full instead of
             padding every request to the longest one.

Two cache layouts share that lifecycle:

``ServeEngine`` (contiguous) owns one ``(n_slots, max_len)`` decode
cache — engine capacity is ``n_slots * max_len`` rows no matter how
short requests are.  ``PagedServeEngine`` owns an ``(n_blocks,
block_len)`` block pool per attention leaf plus per-slot block tables
(``repro.serve.paged``): a request holds exactly the blocks its own
capacity spans, identical prompt prefixes are pooled once (refcounted,
copy-on-write resolved at admission), and slot count is bounded by live
tokens rather than ``n_slots * max_len``.

Slot independence: attention/SSM state and (single-device) MoE routing
never mix batch rows, so a request's tokens are identical to a solo run
with the same per-request PRNG key (tests/test_serve_engine.py asserts
this).  Caveat: the multi-device ``moe_a2a`` path computes expert
capacity over ALL batch rows, so freed garbage lanes could crowd live
tokens out of an expert there — sharded decode is a ROADMAP follow-on
and needs live-token-masked routing first.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serve import paged as pg
from repro.serve.sampling import Greedy


@dataclasses.dataclass
class Request:
    """One generation request.  ``batch`` is a leading-dim-1 prefill
    batch (``tokens`` plus ``patches``/``frames`` for vlm/encdec);
    ``max_new`` counts ALL generated tokens, including the one sampled
    from the prefill logits."""
    uid: int
    batch: Dict[str, Any]
    max_new: int
    key: Optional[Any] = None  # per-request PRNG key (seeded from uid if None)
    # memoised prefix-block content keys (paged engine): hashing the
    # prompt/modality bytes is done once, not per blocked admission retry
    plan_keys: Optional[List] = None

    @property
    def prompt_len(self) -> int:
        return self.batch["tokens"].shape[1]


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: np.ndarray     # (n_generated,) — includes the EOS token if hit
    n_segments: int        # decode segments this request rode through


class CompiledLRU:
    """Bounded per-shape executable cache.

    Under open-world traffic every distinct prompt length compiles (and
    permanently pins) a fresh prefill/admit executable if cached in an
    unbounded ``lru_cache`` — evicting the per-length jitted callable
    here drops its executables with it.
    """

    def __init__(self, build: Callable[[Any], Callable], maxsize: int = 32):
        self._build, self._maxsize = build, max(maxsize, 1)
        self._cache: OrderedDict = OrderedDict()

    def __call__(self, key):
        fn = self._cache.get(key)
        if fn is None:
            fn = self._build(key)
            self._cache[key] = fn
            if len(self._cache) > self._maxsize:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return fn

    def __len__(self) -> int:
        return len(self._cache)


@functools.lru_cache(maxsize=8)
def _prefill_fn(cfg: ModelConfig, mesh):
    """Shared jitted prefill (benchmarks use it for the non-engine serving
    modes).  The engine itself compiles through its bounded per-length
    ``CompiledLRU`` instead, so sustained open-world traffic cannot pin
    an executable per prompt length."""
    return jax.jit(lambda p, b: M.prefill(p, cfg, b, mesh=mesh))


class ServeEngine:
    """Continuous-batching engine over a fixed ``(n_slots, max_len)``
    decode cache.  ``submit()`` requests, then ``run()`` (or ``step()``
    segment-by-segment for external admission control); drain finished
    requests with ``pop_completions()`` under sustained traffic."""

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 128, sampler=None, eos_id: Optional[int] = None,
                 seg_len: int = 8, mesh=None, seed: int = 0,
                 history_limit: int = 4096, compile_cache_size: int = 32):
        cfg.validate()
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len, self.seg_len = n_slots, max_len, seg_len
        self.sampler = sampler if sampler is not None else Greedy()
        self.eos_id, self.mesh = eos_id, mesh
        self._base_key = jax.random.PRNGKey(seed)
        # bounded per-prompt-length executable caches (see CompiledLRU)
        self._prefill_exec = CompiledLRU(self._build_prefill,
                                         compile_cache_size)
        self._admit_exec = CompiledLRU(self._build_admit, compile_cache_size)
        self._init_cache()
        # per-slot host state
        self.tok = np.zeros((n_slots,), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.rem = np.zeros((n_slots,), np.int32)
        self.keys = np.array(jax.random.split(self._base_key, n_slots))
        self.slot_uid = np.full((n_slots,), -1, np.int64)
        self.queue: deque = deque()
        self._pending: set = set()  # queued uids — O(1) reuse check
        self.completions: Dict[int, Completion] = {}
        self.history: deque = deque(maxlen=history_limit)  # (seg, slot, uid)
        self.segment_idx = 0
        self.stats = {"generated_tokens": 0, "segments": 0, "prefills": 0,
                      "slot_steps": 0, "live_slot_steps": 0,
                      "peak_live_requests": 0}
        self._out: Dict[int, list] = {}
        self._plen: Dict[int, int] = {}
        self._nseg: Dict[int, int] = {}
        self._uid_auto = 0

    # -- cache layout hooks (overridden by PagedServeEngine) ---------------

    def _init_cache(self) -> None:
        self.cache = M.init_decode_cache(self.cfg, self.n_slots, self.max_len)

    def _build_prefill(self, P: int):
        cfg, mesh = self.cfg, self.mesh
        return jax.jit(lambda p, b: M.prefill(p, cfg, b, mesh=mesh))

    def _build_admit(self, P: int):
        """Jitted admission: graft a B=1 prefill cache and scatter it
        into row ``slot`` of the engine's batched cache, fused into ONE
        dispatch (batch axis per leaf from ``decode_cache_batch_axes``;
        the batched cache is donated)."""
        cfg, max_len = self.cfg, self.max_len
        axes = M.decode_cache_batch_axes(cfg)

        def admit(cache, pc, slot):
            sub = M.prefill_into_cache(
                cfg, M.init_decode_cache(cfg, 1, max_len), pc)

            def put(dst, src, ax):
                idx = [slice(None)] * dst.ndim
                idx[ax] = slot
                return dst.at[tuple(idx)].set(
                    jnp.take(src, 0, axis=ax).astype(dst.dtype))

            return jax.tree.map(put, cache, sub, axes)

        return jax.jit(admit, donate_argnums=(0,))

    # -- request intake ----------------------------------------------------

    def submit(self, batch, *, max_new: int, uid: Optional[int] = None,
               key=None) -> int:
        if uid is None:
            uid = self._uid_auto
            self._uid_auto += 1
        else:
            self._uid_auto = max(self._uid_auto, uid + 1)
        if uid in self.completions or uid in self._out or uid in self._pending:
            raise ValueError(f"request uid {uid} already in use")
        bad = [k for k, v in batch.items() if v.shape[0] != 1]
        if bad:
            raise ValueError(
                f"request {uid}: batch entries {bad} must have leading dim 1 "
                f"(one request per submit)")
        self._validate_capacity(uid, batch["tokens"].shape[1], max_new)
        if max_new < 1:
            raise ValueError(f"request {uid}: max_new must be >= 1")
        self.queue.append(Request(uid, batch, max_new, key))
        self._pending.add(uid)
        return uid

    def _validate_capacity(self, uid: int, P: int, max_new: int) -> None:
        need = M.decode_capacity(self.cfg, P, max_new)
        if need > self.max_len:
            raise ValueError(
                f"request {uid}: prompt {P} + max_new {max_new} needs cache "
                f"capacity {need} > engine max_len {self.max_len}")

    @property
    def idle(self) -> bool:
        return not self.queue and not (self.slot_uid >= 0).any()

    def pop_completions(self) -> Dict[int, Completion]:
        """Drain finished requests — the bound on ``completions`` growth
        under sustained traffic (their uids become reusable)."""
        out, self.completions = self.completions, {}
        return out

    # -- admission ---------------------------------------------------------

    def _finish(self, uid: int) -> None:
        self.completions[uid] = Completion(
            uid, self._plen.pop(uid),
            np.asarray(self._out.pop(uid), np.int32), self._nseg.pop(uid))

    def _plan(self, req: Request):
        """Admission plan (paged: block keys/counts).  None = no plan."""
        return None

    def _fits(self, plan) -> bool:
        """Can the planned request be placed right now?"""
        return True

    def _place(self, slot: int, req: Request, pc, plan) -> None:
        self.cache = self._admit_exec(req.prompt_len)(self.cache, pc, slot)

    def _release_slot(self, slot: int) -> None:
        self.slot_uid[slot] = -1
        # EOS can finish a slot with budget left: zero it so the freed
        # lane runs masked (done = rem<=0) until re-admitted
        self.rem[slot] = 0

    def _admit(self) -> None:
        free = [s for s in range(self.n_slots) if self.slot_uid[s] < 0]
        while free and self.queue:
            req = self.queue[0]
            plan = self._plan(req)
            if not self._fits(plan):
                break  # blocked on pool space: keep arrival order
            self.queue.popleft()
            self._pending.discard(req.uid)
            logits, pc = self._prefill_exec(req.prompt_len)(self.params,
                                                            req.batch)
            key = req.key if req.key is not None else \
                jax.random.fold_in(self._base_key, req.uid)
            key, k0 = jax.random.split(key)
            e0 = int(np.asarray(self.sampler(k0[None], logits))[0])
            self._out[req.uid] = [e0]
            self._plen[req.uid] = req.prompt_len
            self._nseg[req.uid] = 0
            self.stats["prefills"] += 1
            self.stats["generated_tokens"] += 1
            if req.max_new <= 1 or (self.eos_id is not None
                                    and e0 == self.eos_id):
                self._finish(req.uid)  # done at prefill: no slot needed,
                continue               # skip the cache graft entirely
            slot = free.pop(0)
            self._place(slot, req, pc, plan)
            self.slot_uid[slot] = req.uid
            self.tok[slot] = e0
            self.pos[slot] = M.decode_pos0(self.cfg, req.prompt_len)
            self.rem[slot] = req.max_new - 1
            self.keys[slot] = np.asarray(key)
        self.stats["peak_live_requests"] = max(
            self.stats["peak_live_requests"], int((self.slot_uid >= 0).sum()))

    # -- scanned decode segment --------------------------------------------

    def _run_segment(self):
        return M.generate(self.params, self.cfg, self.cache,
                          jnp.asarray(self.tok), jnp.asarray(self.pos),
                          steps=self.seg_len, sampler=self.sampler,
                          rng=jnp.asarray(self.keys), eos_id=self.eos_id,
                          remaining=jnp.asarray(self.rem), mesh=self.mesh)

    def _segment(self) -> None:
        res = self._run_segment()
        self.cache = res["cache"]
        toks, valid = np.asarray(res["tokens"]), np.asarray(res["valid"])
        done = np.asarray(res["done"])
        # writable copies — _admit() mutates these per slot
        self.tok = np.array(res["next_tok"])
        self.pos = np.array(res["pos"])
        self.rem = np.array(res["remaining"])
        self.keys = np.array(res["rng"])
        for s in range(self.n_slots):
            uid = int(self.slot_uid[s])
            if uid < 0:
                continue
            self.history.append((self.segment_idx, s, uid))
            new = toks[s][valid[s]].tolist()
            self._out[uid].extend(new)
            self._nseg[uid] += 1
            self.stats["generated_tokens"] += len(new)
            self.stats["live_slot_steps"] += len(new)
            if done[s]:
                self._finish(uid)
                self._release_slot(s)
        self.stats["slot_steps"] += self.n_slots * self.seg_len
        self.stats["segments"] += 1
        self.segment_idx += 1

    # -- driving -----------------------------------------------------------

    def step(self) -> None:
        """Admit waiting requests, then run one decode segment."""
        self._admit()
        if (self.slot_uid >= 0).any():
            self._segment()

    def run(self) -> Dict[int, Completion]:
        """Drain the queue: segments with admission in between."""
        t0 = time.perf_counter()
        while not self.idle:
            self.step()
        self.stats["wall_s"] = (self.stats.get("wall_s", 0.0)
                                + time.perf_counter() - t0)
        return self.completions


class PagedServeEngine(ServeEngine):
    """Continuous batching over a block-paged KV cache.

    A request is admitted with exactly the blocks its capacity spans
    (``ceil(decode_capacity / block_len)``), full prompt blocks dedup'd
    against the allocator's content pool, so concurrency is bounded by
    *live tokens* (plus per-request round-up) instead of
    ``n_slots * max_len``.  Block tables are fixed for a request's
    lifetime — segments never allocate — and finished slots' tables are
    pointed back at the trash block before their lanes run on as masked
    garbage.
    """

    def __init__(self, params, cfg: ModelConfig, *, block_len: int = 16,
                 n_blocks: Optional[int] = None, n_slots: int = 4,
                 max_len: int = 128, share_prefix: bool = True, **kw):
        self.block_len = block_len
        self.max_blocks = -(-max_len // block_len)
        # default pool: worst case every slot holds max_len live tokens
        self.n_blocks = (1 + n_slots * self.max_blocks
                         if n_blocks is None else n_blocks)
        self._has_paged = M.has_paged_leaves(cfg)
        self.share_prefix = share_prefix and self._has_paged
        self.alloc = pg.PagedAllocator(self.n_blocks, block_len)
        self.block_tables = np.full((n_slots, self.max_blocks), pg.TRASH,
                                    np.int32)
        self._slot_blocks: Dict[int, List[int]] = {}  # uid -> held block ids
        super().__init__(params, cfg, n_slots=n_slots, max_len=max_len, **kw)
        self.stats.update({"shared_blocks": 0, "fresh_blocks": 0,
                           "peak_live_blocks": 0})

    # -- cache layout ------------------------------------------------------

    def _init_cache(self) -> None:
        self.cache = M.init_paged_cache(self.cfg, self.n_slots, self.n_blocks,
                                        self.block_len)

    def _build_admit(self, P: int):
        cfg, bl = self.cfg, self.block_len
        n_pb = -(-M.decode_pos0(cfg, P) // bl)  # blocks holding prompt rows

        def admit(cache, pc, slot, ids, mask):
            sub = M.prefill_into_cache(
                cfg, M.init_decode_cache(cfg, 1, n_pb * bl), pc)
            return M.scatter_prefill_paged(cfg, cache, sub, slot, ids, mask,
                                           block_len=bl)

        return jax.jit(admit, donate_argnums=(0,))

    # -- admission ---------------------------------------------------------

    def _validate_capacity(self, uid: int, P: int, max_new: int) -> None:
        super()._validate_capacity(uid, P, max_new)
        if not self._has_paged:
            return
        n_total = -(-M.decode_capacity(self.cfg, P, max_new)
                    // self.block_len)
        if n_total > self.n_blocks - 1:
            # admission could otherwise stall forever waiting for blocks
            # the pool can never provide, even with every slot free
            raise ValueError(
                f"request {uid}: needs {n_total} blocks > pool of "
                f"{self.n_blocks - 1} allocatable blocks")

    def _plan(self, req: Request):
        """(keys, n_prompt_blocks, n_total_blocks, n_missing)."""
        if not self._has_paged:
            return ([], 0, 0, 0)
        bl = self.block_len
        pos0 = M.decode_pos0(self.cfg, req.prompt_len)
        cap = M.decode_capacity(self.cfg, req.prompt_len, req.max_new)
        n_total = -(-cap // bl)
        n_pb = -(-pos0 // bl)
        if req.plan_keys is None:
            req.plan_keys = (pg.prefix_keys(req.batch, pos0 // bl, bl,
                                            M.decode_offset(self.cfg))
                             if self.share_prefix else [])
        keys = req.plan_keys
        # the lookup part IS re-evaluated per attempt: pool contents
        # change between segments while the request waits for blocks
        missing = n_total - sum(1 for k in keys
                                if self.alloc.lookup(k) is not None)
        return (keys, n_pb, n_total, missing)

    def _fits(self, plan) -> bool:
        return plan[3] <= self.alloc.n_free

    def _place(self, slot: int, req: Request, pc, plan) -> None:
        keys, n_pb, n_total, _ = plan
        ids, mask = [], []
        for i in range(n_total):
            if i < len(keys):
                bid, fresh = self.alloc.acquire(keys[i])
                self.stats["shared_blocks" if not fresh
                           else "fresh_blocks"] += 1
            else:
                # write frontier onward: always privately owned, so
                # decode writes (and diverged suffixes) never alias
                bid, fresh = self.alloc.alloc(), True
                self.stats["fresh_blocks"] += 1
            ids.append(bid)
            if i < n_pb:
                mask.append(fresh)
        self._slot_blocks[req.uid] = ids
        row = np.full((self.max_blocks,), pg.TRASH, np.int32)
        row[:n_total] = ids
        self.block_tables[slot] = row
        self.stats["peak_live_blocks"] = max(self.stats["peak_live_blocks"],
                                             self.alloc.n_live)
        self.cache = self._admit_exec(req.prompt_len)(
            self.cache, pc, slot, jnp.asarray(ids[:n_pb], jnp.int32),
            jnp.asarray(mask, jnp.bool_))

    def _release_slot(self, slot: int) -> None:
        uid = int(self.slot_uid[slot])
        super()._release_slot(slot)
        for bid in self._slot_blocks.pop(uid, []):
            self.alloc.release(bid)
        # dead lane: writes pin to (trash block, offset 0) until re-admitted
        self.block_tables[slot] = pg.TRASH
        self.pos[slot] = 0

    # -- scanned decode segment --------------------------------------------

    def _run_segment(self):
        return M.generate(self.params, self.cfg, self.cache,
                          jnp.asarray(self.tok), jnp.asarray(self.pos),
                          steps=self.seg_len, sampler=self.sampler,
                          rng=jnp.asarray(self.keys), eos_id=self.eos_id,
                          remaining=jnp.asarray(self.rem), mesh=self.mesh,
                          block_tables=jnp.asarray(self.block_tables))
