"""Per-slot token samplers for the serving engine.

A sampler maps ``(keys, logits) -> tokens`` with per-slot PRNG keys
``(B, 2)`` and logits ``(B, V)`` (f32), returning ``(B,)`` int32 token
ids.  Samplers are **hashable frozen dataclasses**: the compiled scanned
decode (``models.model._generate_fn``) is cached per sampler instance,
so two engines with the same sampler share one executable.

Greedy ignores its keys; Temperature/TopK consume one key per slot per
step — the engine splits each slot's key stream once per decode step
whether or not the slot is live, so a scan cut into segments samples
exactly like one long scan.

Each sampler also exposes ``verify(keys, logits, draft)`` for
self-speculative decode: given the TARGET logits at a drafted position
and the (greedy-drafted) token proposed there, return ``(token,
accepted)``.  Because the drafter is greedy (a point mass), exact
residual rejection sampling reduces to: accept the draft with
probability p(draft) under the target distribution, else resample from
the target with the draft masked out — the emitted marginal is exactly
the target distribution (P(d) = p_d; P(x!=d) = (1-p_d) * p_x/(1-p_d)).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def _greedy_verify(logits, draft):
    tgt = jnp.argmax(logits, -1).astype(jnp.int32)
    return tgt, tgt == draft


def _residual_verify(keys, logits, draft, t):
    def one(key, l, d):
        ka, kb = jax.random.split(key)
        accept = jax.random.uniform(ka) < jax.nn.softmax(l / t)[d]
        alt = jax.random.categorical(kb, l.at[d].set(-jnp.inf) / t)
        return jnp.where(accept, d, alt).astype(jnp.int32), accept

    return jax.vmap(one)(keys, logits, draft)


@dataclasses.dataclass(frozen=True)
class Greedy:
    """Deterministic argmax decoding."""

    def __call__(self, keys, logits):
        del keys
        return jnp.argmax(logits, -1).astype(jnp.int32)

    def verify(self, keys, logits, draft):
        del keys
        return _greedy_verify(logits, draft)


# Below this, logits / t amplifies f32 logits toward overflow and the
# categorical's probabilities degenerate to NaN/one-hot anyway — the
# distribution IS argmax, so dispatch there (t is a static dataclass
# field, so this is a Python-level branch, not a traced one).
ARGMAX_TEMPERATURE = 1e-3


@dataclasses.dataclass(frozen=True)
class Temperature:
    """Sample from softmax(logits / t) with a per-slot key.

    ``t`` at or below ``ARGMAX_TEMPERATURE`` (including t=0) decodes
    greedily instead of dividing by a vanishing temperature."""

    t: float = 1.0

    def __call__(self, keys, logits):
        if self.t <= ARGMAX_TEMPERATURE:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.vmap(
            lambda k, l: jax.random.categorical(k, l / self.t)
        )(keys, logits).astype(jnp.int32)

    def verify(self, keys, logits, draft):
        if self.t <= ARGMAX_TEMPERATURE:
            return _greedy_verify(logits, draft)
        return _residual_verify(keys, logits, draft, self.t)


@dataclasses.dataclass(frozen=True)
class TopK:
    """Restrict to the k most likely tokens, then temperature-sample.

    ``k`` is clamped to the vocab size (``lax.top_k`` raises on k > V)
    and tiny/zero temperatures decode greedily, as in ``Temperature``."""

    k: int = 40
    t: float = 1.0

    def __call__(self, keys, logits):
        if self.t <= ARGMAX_TEMPERATURE:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        k = min(self.k, logits.shape[-1])

        def one(key, l):
            vals, idx = jax.lax.top_k(l, k)
            return idx[jax.random.categorical(key, vals / self.t)]

        return jax.vmap(one)(keys, logits).astype(jnp.int32)

    def verify(self, keys, logits, draft):
        if self.t <= ARGMAX_TEMPERATURE:
            return _greedy_verify(logits, draft)
        k = min(self.k, logits.shape[-1])

        def mask_topk(l):
            vals, idx = jax.lax.top_k(l, k)
            return jnp.full_like(l, -jnp.inf).at[idx].set(vals)

        # a draft outside the top-k has p=0 under the restricted target
        # distribution, so it is always rejected and the resample comes
        # from the top-k set (minus the draft) — still the exact target.
        return _residual_verify(keys, jax.vmap(mask_topk)(logits), draft,
                                self.t)
