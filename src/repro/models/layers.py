"""Core neural layers: norms, RoPE, attention (GQA + MLA), MLPs.

Everything is a pure function over explicit parameter pytrees.  Attention
ships two execution paths:

* a chunked online-softmax ("flash-style") jnp implementation — the XLA
  path used for training / prefill at long sequence lengths without ever
  materialising the (Sq, Sk) score matrix;
* a Pallas TPU kernel (``repro.kernels.flash_attention``) selected with
  ``cfg.use_pallas`` (validated under ``interpret=True`` on CPU).

Decode (single-token query vs. a long cache) uses a direct einsum — it is
O(S) per step and memory-light.
"""
from __future__ import annotations

import logging
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import quant
from repro.models.config import ModelConfig

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-style), stored in model dtype."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int, dtype):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def apply_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d: int):
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# masking helper
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int):
    """Additive bias (..., Sq, Sk) from absolute positions. k_pos < 0 = pad."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = kp >= 0
    if causal:
        ok = ok & (kp <= qp)
    if window:
        ok = ok & (kp > qp - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _softcap(s, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


# ---------------------------------------------------------------------------
# chunked online-softmax attention (XLA flash path)
# ---------------------------------------------------------------------------

def chunked_attention(
    q, k, v, q_pos, k_pos, *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    skip_masked_chunks: bool = False,
    unroll: bool = False,
    remat_chunks: bool = False,
):
    """q: (B,Sq,H,Dq)  k: (B,Sk,KH,Dq)  v: (B,Sk,KH,Dv)  ->  (B,Sq,H,Dv).

    Never materialises (Sq, Sk); accumulates in f32 with a running
    max/denominator (online softmax).  With ``skip_masked_chunks`` the
    (statically known) fully-masked chunk pairs — above the causal
    diagonal, or outside the sliding window — are skipped entirely, which
    halves causal-prefill FLOPs and makes local-attention cost O(S·W).
    """
    B, Sq, H, Dq = q.shape
    _, Sk, KH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(Dq)

    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    # pad to multiples
    Sq_p = -(-Sq // qc) * qc
    Sk_p = -(-Sk // kc) * kc
    q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_pos, ((0, 0), (0, Sq_p - Sq)), constant_values=0)
    k_pos = jnp.pad(k_pos, ((0, 0), (0, Sk_p - Sk)), constant_values=-1)

    nq, nk = Sq_p // qc, Sk_p // kc
    # (B, KH, G, nq, qc, D)
    qr = q.reshape(B, nq, qc, KH, G, Dq).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(B, nk, kc, KH, Dq).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kc, KH, Dv).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(B, nq, qc).transpose(1, 0, 2)
    kp = k_pos.reshape(B, nk, kc).transpose(1, 0, 2)

    def kv_step_inner(carry, inputs, q_blk, qp_blk):
        m, l, o = carry
        k_blk, v_blk, kp_blk = inputs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                       k_blk.astype(jnp.float32)) * scale
        s = _softcap(s, softcap)
        bias = _mask_bias(qp_blk, kp_blk, causal=causal, window=window)
        s = s + bias[:, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    if remat_chunks:
        # recompute s/p during backward: the saved residuals per kv-chunk
        # drop from O(qc*kc) score tensors to the O(qc) m/l/o carries
        kv_step = jax.checkpoint(
            lambda c, i, qb, qpb: kv_step_inner(c, i, qb, qpb),
            static_argnums=())
    else:
        kv_step = kv_step_inner

    def q_step(q_blk, qp_blk, qi):
        m0 = jnp.full((B, KH, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, qc), jnp.float32)
        o0 = jnp.zeros((B, KH, G, qc, Dv), jnp.float32)
        if skip_masked_chunks:
            # static chunk-level visibility: q rows of chunk qi span
            # [qi*qc, qi*qc+qc); k chunk ki spans [ki*kc, ki*kc+kc).
            carry = (m0, l0, o0)
            for ki in range(nk):
                if causal and ki * kc > qi * qc + qc - 1:
                    continue  # entirely above the causal diagonal
                if window and (ki * kc + kc - 1) <= (qi * qc - window):
                    continue  # entirely left of every query's window
                carry, _ = kv_step(carry, (kr[ki], vr[ki], kp[ki]), q_blk, qp_blk)
            m, l, o = carry
        else:
            (m, l, o), _ = jax.lax.scan(
                lambda c, x: kv_step(c, x, q_blk, qp_blk), (m0, l0, o0),
                (kr, vr, kp), unroll=nk if unroll else 1)
        return o / jnp.maximum(l, 1e-30)[..., None]

    if skip_masked_chunks or unroll:
        outs = [q_step(qr[qi], qp[qi], qi) for qi in range(nq)]
        out = jnp.stack(outs, axis=0)
    else:
        out = jax.lax.map(lambda args: q_step(args[0], args[1], 0), (qr, qp))
    # (nq, B, KH, G, qc, Dv) -> (B, Sq, H, Dv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq_p, H, Dv)
    return out[:, :Sq].astype(v.dtype)


def _constrain_seq(x, mesh, dim):
    """Keep a decode score tensor sharded (batch x data, cache-seq x model).

    Without this XLA (on the 16x16 mesh) prefers to ALL-GATHER the KV /
    MLA-latent cache over the "model" axis per layer — for deepseek-v3
    decode_32k that is ~260 GB of ICI traffic per step.  Constraining the
    scores keeps the einsum sequence-sharded; softmax then needs only a
    tiny max/sum all-reduce.  The batch dim must be pinned to the data
    axes at the same time, or XLA replicates the whole score computation
    per device (EXPERIMENTS.md §Perf, iterations D1/D4).
    """
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = [None] * x.ndim
    spec[dim] = "model"
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]
    if n_data > 1 and x.shape[0] % n_data == 0:
        spec[0] = daxes if len(daxes) > 1 else daxes[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def decode_attention(q, k_cache, v_cache, q_pos, k_pos, *,
                     window: int = 0, softcap: float = 0.0,
                     scale: Optional[float] = None, causal: bool = True,
                     mesh=None):
    """Decode/chunk attention.  q: (B,C,H,Dq); caches: (B,S,KH,D*).

    C is 1 for single-token decode; chunked prefill attends C queries
    against the same cache view with per-query positional masking."""
    B, C, H, Dq = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    if scale is None:
        scale = 1.0 / math.sqrt(Dq)
    qr = q.reshape(B, C, KH, G, Dq)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = _constrain_seq(s, mesh, 4)
    s = _softcap(s, softcap)
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window)
    s = s + bias[:, None, None]
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(B, C, H, v_cache.shape[-1]).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    D, H, KH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * Dh), 0, dtype),
        "wk": dense_init(ks[1], (D, KH * Dh), 0, dtype),
        "wv": dense_init(ks[2], (D, KH * Dh), 0, dtype),
        "wo": dense_init(ks[3], (H * Dh, D), 0, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((Dh,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((Dh,), dtype)}
    return p


def attention_qkv(p, cfg: ModelConfig, x, positions):
    B, S, D = x.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    k = (x @ p["wk"]).reshape(B, S, KH, Dh)
    v = (x @ p["wv"]).reshape(B, S, KH, Dh)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q)
        k = apply_norm(p["k_norm"], k)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_full(p, cfg: ModelConfig, x, positions, *, window: int,
                   causal: bool = True):
    """Full-sequence (train / prefill) attention. Returns (out, (k, v))."""
    q, k, v = attention_qkv(p, cfg, x, positions)
    if cfg.use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q, k, v, positions, positions, causal=causal, window=window,
            softcap=cfg.attn_logit_softcap)
    else:
        out = chunked_attention(
            q, k, v, positions, positions, causal=causal, window=window,
            softcap=cfg.attn_logit_softcap,
            q_chunk=cfg.attn_chunk_q, k_chunk=cfg.attn_chunk_k,
            skip_masked_chunks=cfg.attn_skip_masked_chunks,
            unroll=cfg.scan_unroll, remat_chunks=cfg.remat_attn_chunks)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def paged_insert(pool, block_table, pos, entry):
    """Scatter C tokens' cache entries into a block pool.

    pool: (n_blocks, block_len, ...); entry (B, C, ...) at logical
    positions ``pos`` (B, C): position p lives in pool row
    ``block_table[b, p // block_len]`` at offset ``p % block_len``.  The
    engine guarantees the write-frontier blocks of every live slot are
    uniquely owned (shared prefix blocks sit strictly below the
    frontier; a shared-prefix chunked prefill passes a write table whose
    shared rows point at the trash block) and points dead slots at the
    sacrificial trash block 0.  ``pos // block_len`` must stay inside
    the table width — table gathers clamp out-of-bounds, so an
    undersized table would silently alias the last entry's block.
    """
    bl = pool.shape[1]
    bidx = jnp.arange(pos.shape[0])
    blk = block_table[bidx[:, None], pos // bl]          # (B, C)
    return pool.at[blk, pos % bl].set(entry.astype(pool.dtype))


def paged_gather(pool, block_table):
    """Assemble per-slot contiguous views from a block pool.

    (n_blocks, block_len, ...) gathered through (B, nbt) block tables →
    (B, nbt*block_len, ...): gathered index j IS logical position j.
    """
    B = block_table.shape[0]
    return pool[block_table].reshape((B, -1) + pool.shape[2:])


_PAGED_PATH_LOGGED: set = set()


def paged_read_path(cfg: ModelConfig, C: int, attn: str = "gqa") -> str:
    """Which paged-attention read path serves this call: ``"pallas"``
    (the scalar-prefetched block-table kernel) or ``"gather"`` (the
    block-table gather reference).

    The fallback selection is explicit — and logged once per distinct
    reason — so sharded benches can report which path actually ran: the
    Pallas kernel covers GQA at any chunk width (C=1 decode, C>1
    chunked-prefill and speculative-verify chunks — the former gather
    fallback for C>1 is retired), while MLA's latent cache attends
    through the absorbed-matrix gather path.
    """
    if attn == "mla":
        path, why = "gather", "MLA latent layout"
    elif not cfg.use_pallas:
        path, why = "gather", "use_pallas=False"
    elif C != 1:
        path, why = "pallas", f"multi-query chunk (C={C})"
    else:
        path, why = "pallas", "single-query decode"
    if (path, why) not in _PAGED_PATH_LOGGED:
        _PAGED_PATH_LOGGED.add((path, why))
        logging.getLogger(__name__).info(
            "paged_attn read path: %s (%s)", path, why)
    return path


def attention_decode(p, cfg: ModelConfig, x, pos, cache, *,
                     window: int, mesh=None, block_table=None,
                     write_table=None):
    """Decode / chunked-prefill attention.  x: (B,C,D), pos: (B,C).

    C=1 is the single-token decode step; C>1 is one chunked-prefill
    chunk: all C k/v entries are written into the cache first, then the
    C queries attend over the updated view with per-query causal (and
    window) masking — in-chunk causality falls out of the position mask.

    ``cache`` is the layer's cache-entry dict: ``{"k", "v"}`` plus
    ``{"k_scale", "v_scale"}`` under a quantized ``CachePolicy``
    (int8/fp8 data with per-(position, kv-head) float32 scales — see
    ``repro.models.quant``).  Quantized entries are quantized at write
    time, so the same token content always produces the same block
    bytes; reads dequantize the attended view (the Pallas paged path
    fuses the dequant into the kernel).

    Contiguous (``block_table=None``): caches (B,Smax,KH,Dh); inserts
    this chunk's k/v at ``pos`` (per-batch scatter; positions beyond
    Smax — bucket padding — are dropped by the scatter) and attends over
    the updated cache.  Paged: caches are block pools (n_blocks,
    block_len,KH,Dh); inserts through ``write_table`` (defaults to
    ``block_table``; chunked admission points already-pooled shared
    prefix rows at the trash block) and attends over the gathered (or
    Pallas block-table-indexed) view.  Returns (out, new_cache_dict).
    """
    B, C = x.shape[:2]
    q, k, v = attention_qkv(p, cfg, x, pos)
    quantized = "k_scale" in cache
    cache = dict(cache)
    if quantized:
        kv_dtype = quant.kv_dtype_of_leaf(cache["k"])
        k_w, ks_w = quant.quantize(k, kv_dtype)
        v_w, vs_w = quant.quantize(v, kv_dtype)
    else:
        k_w, v_w = k, v
    if block_table is None:
        bidx = jnp.arange(B)
        idx = (bidx[:, None], pos)
        cache["k"] = cache["k"].at[idx].set(k_w.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[idx].set(v_w.astype(cache["v"].dtype))
        if quantized:
            cache["k_scale"] = cache["k_scale"].at[idx].set(ks_w)
            cache["v_scale"] = cache["v_scale"].at[idx].set(vs_w)
            kg = quant.dequantize(cache["k"], cache["k_scale"], x.dtype)
            vg = quant.dequantize(cache["v"], cache["v_scale"], x.dtype)
        else:
            kg, vg = cache["k"], cache["v"]
    else:
        wt = block_table if write_table is None else write_table
        cache["k"] = paged_insert(cache["k"], wt, pos, k_w)
        cache["v"] = paged_insert(cache["v"], wt, pos, v_w)
        if quantized:
            cache["k_scale"] = paged_insert(cache["k_scale"], wt, pos, ks_w)
            cache["v_scale"] = paged_insert(cache["v_scale"], wt, pos, vs_w)
        if paged_read_path(cfg, C) == "pallas":
            # chunk positions are consecutive per slot (decode, chunked
            # prefill, and the speculative verify chunk all are), so the
            # kernel takes the first query's position and derives the rest
            from repro.kernels.paged_attn import ops as pa_ops
            out = pa_ops.paged_decode_attention(
                q, cache["k"], cache["v"], block_table, pos[:, 0],
                window=window, softcap=cfg.attn_logit_softcap,
                k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
                out_dtype=x.dtype if quantized else None)
            return out.reshape(B, C, -1) @ p["wo"], cache
        kg = paged_gather(cache["k"], block_table)
        vg = paged_gather(cache["v"], block_table)
        if quantized:
            kg = quant.dequantize(
                kg, paged_gather(cache["k_scale"], block_table), x.dtype)
            vg = quant.dequantize(
                vg, paged_gather(cache["v_scale"], block_table), x.dtype)
    Smax = kg.shape[1]
    k_pos = jnp.arange(Smax)[None, :].repeat(B, 0)
    out = decode_attention(q, kg, vg, pos, k_pos,
                           window=window, softcap=cfg.attn_logit_softcap,
                           mesh=mesh)
    return out.reshape(B, C, -1) @ p["wo"], cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    D, H = cfg.d_model, cfg.n_heads
    r, pr = cfg.kv_lora_rank, cfg.rope_head_dim
    nd, vd = cfg.nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 7)
    p = {
        "wkv_a": dense_init(ks[0], (D, r + pr), 0, dtype),
        "kv_norm": {"scale": jnp.ones((r,), dtype)},
        "wk_b": dense_init(ks[1], (H, r, nd), 1, dtype),
        "wv_b": dense_init(ks[2], (H, r, vd), 1, dtype),
        "wo": dense_init(ks[3], (H * vd, D), 0, dtype),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[4], (D, cfg.q_lora_rank), 0, dtype)
        p["q_norm"] = {"scale": jnp.ones((cfg.q_lora_rank,), dtype)}
        p["wq_b"] = dense_init(ks[5], (cfg.q_lora_rank, H * (nd + pr)), 0, dtype)
    else:
        p["wq"] = dense_init(ks[6], (D, H * (nd + pr)), 0, dtype)
    return p


def _mla_queries(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H, nd, pr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        q = apply_norm(p["q_norm"], x @ p["wq_a"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, H, nd + pr)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_latent(p, cfg: ModelConfig, x, positions):
    """Compressed KV: returns (ckv (B,S,r), k_rope (B,S,pr))."""
    r = cfg.kv_lora_rank
    kv = x @ p["wkv_a"]
    ckv = apply_norm(p["kv_norm"], kv[..., :r])
    k_rope = apply_rope(kv[..., None, r:], positions, cfg.rope_theta)[..., 0, :]
    return ckv, k_rope


def mla_full(p, cfg: ModelConfig, x, positions):
    """Training / prefill MLA.  Returns (out, (ckv, k_rope))."""
    B, S, _ = x.shape
    H, nd, pr, vd = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_queries(p, cfg, x, positions)
    ckv, k_rope = mla_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,hrn->bshn", ckv, p["wk_b"].astype(ckv.dtype))
    v = jnp.einsum("bsr,hrv->bshv", ckv, p["wv_b"].astype(ckv.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, pr))], axis=-1)
    out = chunked_attention(
        q, k, v, positions, positions, causal=True,
        scale=1.0 / math.sqrt(nd + pr),
        q_chunk=cfg.attn_chunk_q, k_chunk=cfg.attn_chunk_k,
        skip_masked_chunks=cfg.attn_skip_masked_chunks,
        unroll=cfg.scan_unroll, remat_chunks=cfg.remat_attn_chunks)
    return out.reshape(B, S, H * vd) @ p["wo"], (ckv, k_rope)


def _mla_attend(p, cfg: ModelConfig, x, pos, ckv, krope, mesh):
    """Absorbed-matrix attention over a (B, S, r)/(B, S, pr) latent view
    whose index along S is the logical position (contiguous cache, or a
    block-table gather of a paged pool).  x: (B,C,D), pos: (B,C) — C>1
    is one chunked-prefill chunk, masked causally per query."""
    B, C = x.shape[:2]
    H, nd, pr, vd = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_queries(p, cfg, x, pos)
    # absorb W_UK into the query:  (B,C,H,nd) x (H,r,nd) -> (B,C,H,r)
    q_lat = jnp.einsum("bqhn,hrn->bqhr", q_nope, p["wk_b"].astype(q_nope.dtype))
    Smax = ckv.shape[1]
    k_pos = jnp.arange(Smax)[None, :].repeat(B, 0)
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bqhp,bsp->bhqs", q_rope.astype(jnp.float32),
                      krope.astype(jnp.float32)))
    s = _constrain_seq(s, mesh, 3)
    s = s / math.sqrt(nd + pr)
    s = s + _mask_bias(pos, k_pos, causal=True, window=0)[:, None]
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", w, ckv.astype(jnp.float32))
    v = jnp.einsum("bqhr,hrv->bqhv", ctx, p["wv_b"].astype(jnp.float32))
    return v.reshape(B, C, H * vd).astype(x.dtype) @ p["wo"]


def mla_decode(p, cfg: ModelConfig, x, pos, cache,
               mesh=None, block_table=None, write_table=None):
    """Absorbed-matrix MLA decode: attends directly in the latent space.

    The 576-float/token latent cache is what makes DeepSeek-V3 long-context
    decode feasible (long_500k).  ``cache`` is the layer's cache-entry
    dict: ``{"ckv", "kr"}`` plus ``{"ckv_scale", "kr_scale"}`` under a
    quantized policy (per-position scales over the latent/rope feature
    axis).  Inserts this chunk's latents (x (B,C,D) at pos (B,C); C=1 is
    plain decode), attends, and returns (out, new_cache_dict).  With
    ``block_table`` the caches are block pools and the attended view is
    the gathered one; ``write_table`` (chunked admission) diverts
    already-pooled shared prefix writes.
    """
    B = x.shape[0]
    ckv_t, krope_t = mla_latent(p, cfg, x, pos)
    quantized = "ckv_scale" in cache
    cache = dict(cache)
    if quantized:
        kv_dtype = quant.kv_dtype_of_leaf(cache["ckv"])
        ckv_w, cs_w = quant.quantize(ckv_t, kv_dtype)
        kr_w, krs_w = quant.quantize(krope_t, kv_dtype)
    else:
        ckv_w, kr_w = ckv_t, krope_t
    if block_table is None:
        bidx = jnp.arange(B)
        idx = (bidx[:, None], pos)
        cache["ckv"] = cache["ckv"].at[idx].set(ckv_w.astype(cache["ckv"].dtype))
        cache["kr"] = cache["kr"].at[idx].set(kr_w.astype(cache["kr"].dtype))
        if quantized:
            cache["ckv_scale"] = cache["ckv_scale"].at[idx].set(cs_w)
            cache["kr_scale"] = cache["kr_scale"].at[idx].set(krs_w)
            ckv_g = quant.dequantize(cache["ckv"], cache["ckv_scale"], x.dtype)
            krope_g = quant.dequantize(cache["kr"], cache["kr_scale"], x.dtype)
        else:
            ckv_g, krope_g = cache["ckv"], cache["kr"]
    else:
        wt = block_table if write_table is None else write_table
        cache["ckv"] = paged_insert(cache["ckv"], wt, pos, ckv_w)
        cache["kr"] = paged_insert(cache["kr"], wt, pos, kr_w)
        if quantized:
            cache["ckv_scale"] = paged_insert(cache["ckv_scale"], wt, pos, cs_w)
            cache["kr_scale"] = paged_insert(cache["kr_scale"], wt, pos, krs_w)
        paged_read_path(cfg, x.shape[1], attn="mla")
        ckv_g = paged_gather(cache["ckv"], block_table)
        krope_g = paged_gather(cache["kr"], block_table)
        if quantized:
            ckv_g = quant.dequantize(
                ckv_g, paged_gather(cache["ckv_scale"], block_table), x.dtype)
            krope_g = quant.dequantize(
                krope_g, paged_gather(cache["kr_scale"], block_table), x.dtype)
    out = _mla_attend(p, cfg, x, pos, ckv_g, krope_g, mesh)
    return out, cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_in: int, d_hidden: int, dtype):
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        return {
            "wi_gate": dense_init(ks[0], (d_in, d_hidden), 0, dtype),
            "wi_up": dense_init(ks[1], (d_in, d_hidden), 0, dtype),
            "wo": dense_init(ks[2], (d_hidden, d_in), 0, dtype),
        }
    return {
        "wi": dense_init(ks[0], (d_in, d_hidden), 0, dtype),
        "wo": dense_init(ks[2], (d_hidden, d_in), 0, dtype),
    }


def _act(cfg: ModelConfig, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def apply_mlp(p, cfg: ModelConfig, x):
    if "wi_gate" in p:
        h = _act(cfg, x @ p["wi_gate"]) * (x @ p["wi_up"])
    else:
        h = _act(cfg, x @ p["wi"])
    return h @ p["wo"]
