"""Storage dtype policies for decode caches and optimizer moments.

A ``CachePolicy`` names the storage dtype of the attention KV leaves in
a decode cache (contiguous or paged).  Quantized policies (int8 / fp8)
store each KV row with a per-(position, kv-head) float32 scale computed
at WRITE time — amax over the leaf's trailing feature axis — so every
row dequantizes as ``q.astype(f32) * scale``.  The scale rides the
cache as a sibling leaf keyed ``<leaf>_scale`` (e.g. ``k`` ->
``k_scale``): structure carries policy, so compiled functions retrace
per pytree structure and never need an explicit policy key, and
``policy_of`` recovers the policy from any cache at runtime.

Scales are per-position (not per-block): a block's bytes are then a
pure function of its token content, which keeps the paged allocator's
content-keyed prefix sharing sound — re-writing the same tokens
produces bit-identical blocks regardless of write order.

``bf16`` / ``fp32`` policies are *transparent*: they change only the
leaf dtype (every write path already ``.astype``s into the cache
dtype) and add no scale leaves.  ``""`` (default) keeps the param
dtype — byte-for-byte the historical layout.

``MomentPolicy`` is the optimizer-state analogue (see
``repro.optim.adamw``): first/second AdamW moments in bf16, or the
second moment in int8 with one per-tensor float32 scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# symmetric quantization ranges: int8 uses the full signed byte minus
# the asymmetric -128; fp8 e4m3 (no infinities) saturates at +-448
QMAX = {"int8": 127.0, "fp8": 448.0}
KV_DTYPES = ("", "fp32", "bf16", "fp8", "int8")
_STORAGE = {"fp32": jnp.float32, "bf16": jnp.bfloat16, "int8": jnp.int8}
# guard against zero rows: amax 0 would make scale 0 and dequant NaN-free
# but division at quantize time 0/0
_EPS = 1e-12


def _fp8_dtype():
    return jnp.float8_e4m3fn


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    """KV-cache storage policy.  ``kv_dtype`` in ``KV_DTYPES``."""
    kv_dtype: str = ""

    def __post_init__(self):
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype {self.kv_dtype!r} not in {KV_DTYPES}")

    @property
    def quantized(self) -> bool:
        return self.kv_dtype in ("int8", "fp8")

    @property
    def qmax(self) -> float:
        return QMAX[self.kv_dtype]

    def storage_dtype(self, param_dtype):
        """The dtype KV leaves are allocated at (param dtype when '')."""
        if not self.kv_dtype:
            return jnp.dtype(param_dtype)
        if self.kv_dtype == "fp8":
            return jnp.dtype(_fp8_dtype())
        return jnp.dtype(_STORAGE[self.kv_dtype])


def quantize(x, kv_dtype: str) -> Tuple[jax.Array, jax.Array]:
    """Quantize ``x`` along its LAST axis.

    Returns ``(q, scale)`` with ``q.shape == x.shape`` at the storage
    dtype and ``scale.shape == x.shape[:-1]`` in float32, such that
    ``dequantize(q, scale) ~= x`` with per-row relative error bounded
    by ~1/(2*qmax) for int8 and fp8's 3 mantissa bits for fp8.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, _EPS) / QMAX[kv_dtype]
    q = xf / scale[..., None]
    if kv_dtype == "int8":
        q = jnp.clip(jnp.round(q), -127.0, 127.0).astype(jnp.int8)
    else:
        q = jnp.clip(q, -448.0, 448.0).astype(_fp8_dtype())
    return q, scale.astype(jnp.float32)


def dequantize(q, scale, dtype=jnp.float32):
    """Inverse of ``quantize``: per-row rescale back to ``dtype``."""
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


def kv_dtype_of_leaf(leaf) -> str:
    """The quantized policy a DATA leaf's dtype implies ('' if none)."""
    if leaf.dtype == jnp.int8:
        return "int8"
    if leaf.dtype == jnp.dtype(_fp8_dtype()):
        return "fp8"
    return ""


def policy_of(cache) -> CachePolicy:
    """Recover the CachePolicy from a cache's structure.

    Quantized caches carry ``<leaf>_scale`` siblings; the paired data
    leaf's dtype names the policy.  Caches without scale leaves map to
    the transparent default policy (which also covers bf16/fp32 —
    their runtime behavior is dtype-generic ``.astype``).
    """
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        last = path[-1]
        key = getattr(last, "key", None)
        if isinstance(key, str) and key.endswith("_scale"):
            kv = kv_dtype_of_leaf(_sibling(cache, path, key[:-len("_scale")]))
            if kv:
                return CachePolicy(kv)
    return CachePolicy()


def _sibling(cache, path, name: str):
    """The leaf at ``path`` with its final dict key replaced by ``name``."""
    node = cache
    for entry in path[:-1]:
        node = node[entry.key] if hasattr(entry, "key") else node[entry.idx]
    return node[name]


def is_scale_key(key: str) -> bool:
    return key.endswith("_scale")


def scale_name(key: str) -> str:
    return key + "_scale"


# ---------------------------------------------------------------------------
# optimizer-state policy (used by repro.optim.adamw)
# ---------------------------------------------------------------------------

MOMENT_DTYPES = ("", "fp32", "bf16", "int8")


@dataclasses.dataclass(frozen=True)
class MomentPolicy:
    """AdamW moment storage policy.

    ``m_dtype`` applies to the first moment (bf16 halves it; int8 is
    not offered — sign-sensitive EMA of gradients degrades too fast).
    ``v_dtype`` applies to the second moment; ``int8`` stores v with
    ONE per-tensor float32 scale leaf (v is non-negative and smooth,
    so a per-tensor amax EMA-free snapshot round-trips within the
    Adam epsilon for the fleet's training horizons).
    """
    m_dtype: str = ""
    v_dtype: str = ""

    def __post_init__(self):
        if self.m_dtype not in ("", "fp32", "bf16"):
            raise ValueError(f"m_dtype {self.m_dtype!r} invalid")
        if self.v_dtype not in MOMENT_DTYPES:
            raise ValueError(f"v_dtype {self.v_dtype!r} invalid")

    @property
    def v_quantized(self) -> bool:
        return self.v_dtype == "int8"

    def m_storage(self):
        return {"": jnp.float32, "fp32": jnp.float32,
                "bf16": jnp.bfloat16}[self.m_dtype or ""]

    def v_storage(self):
        if self.v_dtype == "int8":
            return jnp.int8
        return {"": jnp.float32, "fp32": jnp.float32,
                "bf16": jnp.bfloat16}[self.v_dtype or ""]


# log-level span of the int8 v codebook: level 1 sits 6 decades of
# sqrt(v) below the per-tensor amax (level 127); ~11% relative
# resolution per level on sqrt(v) — the quantity the Adam update
# consumes.  Linear levels would round small v entries to 0 and turn
# ``m / (sqrt(v) + eps)`` into a giant sign-SGD step.
_V_ALPHA = 13.815511  # ln(1e6)


def quantize_v(v_f32):
    """Per-tensor int8 quantization of a (non-negative) second moment.

    Codes are **log-spaced in the sqrt domain**: code q > 0 decodes to
    ``scale * exp(_V_ALPHA * (q - 127) / 127)`` of sqrt(v) (code 127 =
    the tensor's amax, code 1 ≈ amax * 1e-6); code 0 is exact zero, so
    freshly-initialized state round-trips bit-exact.  Entries below the
    codebook floor saturate UP to code 1 — overestimating tiny v
    underestimates the step, which is conservative and stable, unlike a
    zero floor feeding ``eps`` into the denominator.  Returns
    ``(q, scale)`` with scalar float32 ``scale``.
    """
    r = jnp.sqrt(v_f32)
    scale = jnp.maximum(jnp.max(r), _EPS)
    lvl = 127.0 + jnp.log(jnp.maximum(r, _EPS) / scale) * (127.0 / _V_ALPHA)
    q = jnp.clip(jnp.round(lvl), 1.0, 127.0)
    q = jnp.where(r > 0, q, 0.0).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_v(q, scale):
    qf = q.astype(jnp.float32)
    r = scale.astype(jnp.float32) * jnp.exp(_V_ALPHA * (qf - 127.0) / 127.0)
    return jnp.where(q > 0, jnp.square(r), 0.0)
