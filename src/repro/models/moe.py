"""Mixture-of-Experts FFN layer (routed + shared experts).

Two execution paths:

* ``dense`` — every expert computes every token, combined with routing
  weights.  O(E) waste; used only for tiny CPU test configs (E <= 8).
* ``a2a``  — TPU-native expert parallelism inside ``shard_map``: tokens
  live on the "data" axis, experts are sharded over the "model" axis.
  Each device packs its tokens into fixed-capacity per-expert buffers,
  a ``lax.all_to_all`` over "model" moves them to the expert owners, a
  batched (E_local, cap, D) x (E_local, D, F) einsum runs the expert
  FFNs on the MXU, and the reverse all_to_all brings results home.
  Capacity overflow drops tokens (GShard semantics, residual passes
  through).  This is the mapping of the paper's DeepSpeed-MoE server
  onto ICI collectives instead of NCCL.

Experts whose count does not divide the "model" axis are padded with
dummy experts whose router logits are masked to -inf.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.moe_dispatch.ops import (capacity_positions,
                                            token_combine, token_dispatch)
from repro.models.config import ModelConfig
from repro.models import layers


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    F = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (D, E), 0, jnp.float32),
        "wi_gate": layers.dense_init(ks[1], (E, D, F), 1, dtype),
        "wi_up": layers.dense_init(ks[2], (E, D, F), 1, dtype),
        "wo": layers.dense_init(ks[3], (E, F, D), 1, dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(ks[4], cfg, D, F * cfg.n_shared_experts, dtype)
    return p


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def route(p, cfg: ModelConfig, x, live=None):
    """Returns (weights (T,k), expert_idx (T,k), aux_loss scalar).

    x: (T, D) flat tokens.  Softmax-then-topk routing with the standard
    load-balance auxiliary loss (GShard / Switch style).

    ``live`` (T,) bool marks rows that belong to live engine slots
    (serving): dead rows' routing weights are zeroed, so whatever a
    freed slot's garbage lane computes is combined with weight 0 — in
    concert with the ``valid=`` mask of ``capacity_positions`` this
    makes dead lanes invisible to every MoE path.
    """
    logits = x.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    if live is not None:
        w = jnp.where(live[:, None], w, 0.0)
    # aux load-balance loss: E * sum_e f_e * p_e
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    one_hot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (T,k,E)
    fe = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)  # fraction routed per expert
    aux = E * jnp.sum(me * fe) * cfg.router_aux_coef
    return w, idx, aux


def _expert_ffn(cfg: ModelConfig, wg, wu, wo, x):
    """Batched expert FFN: x (E, C, D), weights (E, D, F)/(E, F, D)."""
    h = layers._act(cfg, jnp.einsum("ecd,edf->ecf", x, wg))
    h = h * jnp.einsum("ecd,edf->ecf", x, wu)
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ---------------------------------------------------------------------------
# dense path (tests / tiny configs)
# ---------------------------------------------------------------------------

def moe_dense(p, cfg: ModelConfig, x, live=None):
    """x: (B, S, D).  Computes all experts on all tokens (small E only).
    Routing is per-token here, so ``live`` only zeroes dead rows'
    combine weights (no cross-row capacity to protect)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    w, idx, aux = route(p, cfg, xt,
                        None if live is None else live.reshape(-1))
    if cfg.use_pallas:
        from repro.kernels.moe_gemm import ops as moe_ops
        out = moe_ops.moe_ffn(xt, w, idx, p["wi_gate"], p["wi_up"], p["wo"],
                              act=cfg.act)
    else:
        # (E, T, D) all-experts compute
        h = jnp.einsum("td,edf->etf", xt, p["wi_gate"])
        h = layers._act(cfg, h) * jnp.einsum("td,edf->etf", xt, p["wi_up"])
        y_all = jnp.einsum("etf,efd->etd", h, p["wo"])  # (E, T, D)
        one_hot = jax.nn.one_hot(idx, cfg.n_experts, dtype=xt.dtype)  # (T,k,E)
        comb = jnp.einsum("tk,tke->te", w.astype(xt.dtype), one_hot)
        out = jnp.einsum("te,etd->td", comb, y_all)
    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + layers.apply_mlp(p["shared"], cfg, x)
    return out, aux


# ---------------------------------------------------------------------------
# all-to-all expert-parallel path (shard_map over the "model" axis)
# ---------------------------------------------------------------------------

def _pad_experts(E: int, ep: int) -> int:
    return -(-E // ep) * ep


def _capacity(cfg: ModelConfig, t_loc: int, E_pad: int, *, align: int) -> int:
    """Per-(source device, expert) buffer slots.  ``moe_dropless`` sizes
    for the worst case (every local assignment hits one expert) so the
    keep mask can never drop a token — serving's requirement; the
    default is the GShard ``capacity_factor`` drop tradeoff."""
    if cfg.moe_dropless:
        cap = max(t_loc * cfg.top_k, 1)
    else:
        cap = max(int(math.ceil(t_loc * cfg.top_k * cfg.capacity_factor
                                / E_pad)), 4)
    return -(-cap // align) * align


def _a2a_dispatch(xt, flat_tok, slot, keep, *, cfg: ModelConfig,
                  ep_axis: str, ep_size: int, E_loc: int, cap: int):
    """Stage 1: pack tokens into per-(device, expert, capacity-slot)
    buffers and all_to_all them to their expert owners."""
    D = xt.shape[1]
    buf = token_dispatch(xt, flat_tok, slot, keep, ep_size * E_loc * cap,
                         use_kernel=cfg.use_pallas)
    buf = buf.reshape(ep_size, E_loc * cap, D)
    recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)       # (ep_size, E_loc*cap, D)
    recv = recv.reshape(ep_size, E_loc, cap, D).transpose(1, 0, 2, 3)
    return recv.reshape(E_loc, ep_size * cap, D)


def _a2a_ffn(recv, wg, wu, wo, *, cfg: ModelConfig):
    """Stage 2: batched expert FFN on the owner device (MXU einsum or
    the Pallas grouped kernel)."""
    if cfg.use_pallas:
        from repro.kernels.moe_gemm import ops as moe_ops
        return moe_ops.grouped_ffn(recv, wg, wu, wo, act=cfg.act)
    return _expert_ffn(cfg, wg, wu, wo, recv)


def _a2a_combine(y, flat_tok, slot, keep, w, n_tokens, *, cfg: ModelConfig,
                 ep_axis: str, ep_size: int, E_loc: int, cap: int):
    """Stage 3: reverse all_to_all and weighted unpack back to tokens."""
    D = y.shape[-1]
    y = y.reshape(E_loc, ep_size, cap, D).transpose(1, 0, 2, 3)
    y = y.reshape(ep_size, E_loc * cap, D)
    back = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)       # (ep_size, E_loc*cap, D)
    return token_combine(back.reshape(ep_size * E_loc * cap, D), flat_tok,
                         slot, keep, w.reshape(-1), n_tokens,
                         use_kernel=cfg.use_pallas)


def _a2a_local(xt, w, idx, live, wg, wu, wo, *, cfg: ModelConfig,
               ep_axis: str, ep_size: int, capacity: int):
    """Per-device body under shard_map: dispatch / FFN / combine stages
    (split so an overlapped decode step can interleave the all_to_alls
    of one batch half with the attention compute of the other).

    xt:  (T_loc, D) local tokens            [sharded over "data"]
    idx: (T_loc, k) global expert ids       [local]
    live: (T_loc,) bool liveness mask       [sharded over "data"]
    wg/wu/wo: (E_loc, D, F) local expert weights [sharded over "model"]
    """
    T, D = xt.shape
    k = idx.shape[1]
    E_loc = wg.shape[0]
    cap = capacity

    # --- routing layout: per (destination device, local expert, slot) ---
    flat_e = idx.reshape(-1)                     # (T*k,) global expert id
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    # dead rows (freed engine slots) neither hold a capacity rank nor
    # survive the keep mask: they cannot steal an expert's capacity from
    # a live token on any device
    pos, keep = capacity_positions(flat_e, cap, valid=jnp.repeat(live, k))
    # flat buffer layout: (ep_size * E_loc * cap); dest device major
    slot = flat_e * cap + pos                    # == dest*(E_loc*cap) + ...

    stage = dict(cfg=cfg, ep_axis=ep_axis, ep_size=ep_size, E_loc=E_loc,
                 cap=cap)
    recv = _a2a_dispatch(xt, flat_tok, slot, keep, **stage)
    y = _a2a_ffn(recv, wg, wu, wo, cfg=cfg)      # (E_loc, ep*cap, D)
    out = _a2a_combine(y, flat_tok, slot, keep, w, T, **stage)
    return out.astype(xt.dtype)


def moe_a2a(p, cfg: ModelConfig, x, mesh, *, data_axes=("data",),
            ep_axis: str = "model", live=None):
    """x: (B, S, D) with batch sharded over `data_axes`.  ``live``
    (B, S) bool masks dead serving lanes out of routing weights AND
    per-device capacity ranks (see ``_a2a_local``)."""
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E = cfg.n_experts
    ep_size = mesh.shape[ep_axis]
    E_pad = _pad_experts(E, ep_size)
    E_loc = E_pad // ep_size

    xt = x.reshape(-1, D)
    live_t = (jnp.ones((B * S,), jnp.bool_) if live is None
              else live.reshape(-1))
    w, idx, aux = route(p, cfg, xt, None if live is None else live_t)

    # static per-device capacity: tokens_per_device * k * cf / E_pad
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    if (B * S) % n_data != 0:
        # tiny decode batches (e.g. long_500k, B*S=1) replicate tokens;
        # the a2a round-trip still lands every token on its expert owner.
        data_axes, n_data = (), 1
    t_loc = max((B * S) // n_data, 1)
    cap = _capacity(cfg, t_loc, E_pad, align=8)  # MXU-aligned

    wg, wu, wo = p["wi_gate"], p["wi_up"], p["wo"]
    if E_pad != E:
        padn = E_pad - E
        wg = jnp.pad(wg, ((0, padn), (0, 0), (0, 0)))
        wu = jnp.pad(wu, ((0, padn), (0, 0), (0, 0)))
        wo = jnp.pad(wo, ((0, padn), (0, 0), (0, 0)))

    if not data_axes:
        dspec = P(None)
    elif len(data_axes) > 1:
        dspec = P(data_axes)
    else:
        dspec = P(data_axes[0])
    body = functools.partial(_a2a_local, cfg=cfg, ep_axis=ep_axis,
                             ep_size=ep_size, capacity=cap)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(dspec, dspec, dspec, dspec,
                  P(ep_axis), P(ep_axis), P(ep_axis)),
        out_specs=dspec,
        check_rep=False,
    )(xt, w, idx, live_t, wg, wu, wo)

    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + layers.apply_mlp(p["shared"], cfg, x)
    return out, aux


def _replicated_ep_local(xt, w, idx, live, wg, wu, wo, *, cfg: ModelConfig,
                         axes, capacity: int):
    """Serving-layout expert parallelism: tokens REPLICATED on every
    device, experts sharded 1-per-device across ALL mesh axes, outputs
    combined with one small psum.  No weight collectives at all — the
    layout that makes 671B-class MoE decode ICI-cheap (EXPERIMENTS.md
    §Perf, iteration D2)."""
    T, D = xt.shape
    k = idx.shape[1]
    E_loc = wg.shape[0]
    cap = capacity
    dev = jax.lax.axis_index(axes)

    flat_e = idx.reshape(-1)
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    pos, fits = capacity_positions(flat_e, cap, valid=jnp.repeat(live, k))
    local = (flat_e // E_loc) == dev
    keep = local & fits
    slot = jnp.where(local, flat_e % E_loc, 0) * cap + pos
    buf = token_dispatch(xt, flat_tok, slot, keep, E_loc * cap,
                         use_kernel=cfg.use_pallas)
    buf = buf.reshape(E_loc, cap, D)
    if cfg.use_pallas:
        from repro.kernels.moe_gemm import ops as moe_ops
        y = moe_ops.grouped_ffn(buf, wg, wu, wo, act=cfg.act)
    else:
        y = _expert_ffn(cfg, wg, wu, wo, buf)
    out = token_combine(y.reshape(E_loc * cap, D), flat_tok, slot, keep,
                        w.reshape(-1), T, use_kernel=cfg.use_pallas)
    return jax.lax.psum(out.astype(xt.dtype), axes)


def moe_replicated_ep(p, cfg: ModelConfig, x, mesh, live=None):
    """Decode-path MoE: see _replicated_ep_local."""
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E = cfg.n_experts
    n_dev = mesh.size
    axes = tuple(mesh.axis_names)
    E_pad = _pad_experts(E, n_dev)
    E_loc = E_pad // n_dev

    xt = x.reshape(-1, D)
    live_t = (jnp.ones((B * S,), jnp.bool_) if live is None
              else live.reshape(-1))
    w, idx, aux = route(p, cfg, xt, None if live is None else live_t)
    T = xt.shape[0]
    if cfg.moe_dropless:
        cap = _capacity(cfg, T, E_pad, align=4)
    else:
        cap = max(int(math.ceil(T * cfg.top_k * cfg.capacity_factor
                                / E_pad)), 4)
        cap = min(-(-cap // 4) * 4, max(T, 4))

    wg, wu, wo = p["wi_gate"], p["wi_up"], p["wo"]
    if E_pad != E:
        padn = E_pad - E
        wg = jnp.pad(wg, ((0, padn), (0, 0), (0, 0)))
        wu = jnp.pad(wu, ((0, padn), (0, 0), (0, 0)))
        wo = jnp.pad(wo, ((0, padn), (0, 0), (0, 0)))

    body = functools.partial(_replicated_ep_local, cfg=cfg, axes=axes,
                             capacity=cap)
    espec = P(axes)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(None), P(None), P(None), P(None), espec, espec, espec),
        out_specs=P(None),
        check_rep=False,
    )(xt, w, idx, live_t, wg, wu, wo)
    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + layers.apply_mlp(p["shared"], cfg, x)
    return out, aux


def apply_moe(p, cfg: ModelConfig, x, mesh=None, live=None):
    """Dispatch to a MoE execution path.

    ``live`` (B, S) bool is the serving liveness mask: rows of freed
    engine slots are zeroed out of routing weights and excluded from
    per-device expert-capacity accounting on every path.  None (the
    training / prefill default) means all rows are live and is
    bit-identical to the pre-mask behavior.
    """
    impl = cfg.moe_impl
    if impl == "auto":
        impl = "a2a" if (mesh is not None and "model" in mesh.axis_names
                         and mesh.size > 1) else "dense"
    if impl == "replicated_ep":
        return moe_replicated_ep(p, cfg, x, mesh, live)
    if impl == "a2a":
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return moe_a2a(p, cfg, x, mesh, data_axes=data_axes, live=live)
    return moe_dense(p, cfg, x, live)
