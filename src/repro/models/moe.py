"""Mixture-of-Experts FFN layer (routed + shared experts).

Two execution paths:

* ``dense`` — every expert computes every token, combined with routing
  weights.  O(E) waste; used only for tiny CPU test configs (E <= 8).
* ``a2a``  — TPU-native expert parallelism inside ``shard_map``: tokens
  live on the "data" axis, experts are sharded over the "model" axis.
  Each device packs its tokens into fixed-capacity per-expert buffers,
  a ``lax.all_to_all`` over "model" moves them to the expert owners, a
  batched (E_local, cap, D) x (E_local, D, F) einsum runs the expert
  FFNs on the MXU, and the reverse all_to_all brings results home.
  Capacity overflow drops tokens (GShard semantics, residual passes
  through).  This is the mapping of the paper's DeepSpeed-MoE server
  onto ICI collectives instead of NCCL.

Experts whose count does not divide the "model" axis are padded with
dummy experts whose router logits are masked to -inf.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.moe_dispatch.ops import (capacity_positions,
                                            token_combine, token_dispatch)
from repro.models.config import ModelConfig
from repro.models import layers


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    F = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (D, E), 0, jnp.float32),
        "wi_gate": layers.dense_init(ks[1], (E, D, F), 1, dtype),
        "wi_up": layers.dense_init(ks[2], (E, D, F), 1, dtype),
        "wo": layers.dense_init(ks[3], (E, F, D), 1, dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(ks[4], cfg, D, F * cfg.n_shared_experts, dtype)
    return p


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def route(p, cfg: ModelConfig, x):
    """Returns (weights (T,k), expert_idx (T,k), aux_loss scalar).

    x: (T, D) flat tokens.  Softmax-then-topk routing with the standard
    load-balance auxiliary loss (GShard / Switch style).
    """
    logits = x.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # aux load-balance loss: E * sum_e f_e * p_e
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    one_hot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (T,k,E)
    fe = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)  # fraction routed per expert
    aux = E * jnp.sum(me * fe) * cfg.router_aux_coef
    return w, idx, aux


def _expert_ffn(cfg: ModelConfig, wg, wu, wo, x):
    """Batched expert FFN: x (E, C, D), weights (E, D, F)/(E, F, D)."""
    h = layers._act(cfg, jnp.einsum("ecd,edf->ecf", x, wg))
    h = h * jnp.einsum("ecd,edf->ecf", x, wu)
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ---------------------------------------------------------------------------
# dense path (tests / tiny configs)
# ---------------------------------------------------------------------------

def moe_dense(p, cfg: ModelConfig, x):
    """x: (B, S, D).  Computes all experts on all tokens (small E only)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    w, idx, aux = route(p, cfg, xt)
    if cfg.use_pallas:
        from repro.kernels.moe_gemm import ops as moe_ops
        out = moe_ops.moe_ffn(xt, w, idx, p["wi_gate"], p["wi_up"], p["wo"],
                              act=cfg.act)
    else:
        # (E, T, D) all-experts compute
        h = jnp.einsum("td,edf->etf", xt, p["wi_gate"])
        h = layers._act(cfg, h) * jnp.einsum("td,edf->etf", xt, p["wi_up"])
        y_all = jnp.einsum("etf,efd->etd", h, p["wo"])  # (E, T, D)
        one_hot = jax.nn.one_hot(idx, cfg.n_experts, dtype=xt.dtype)  # (T,k,E)
        comb = jnp.einsum("tk,tke->te", w.astype(xt.dtype), one_hot)
        out = jnp.einsum("te,etd->td", comb, y_all)
    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + layers.apply_mlp(p["shared"], cfg, x)
    return out, aux


# ---------------------------------------------------------------------------
# all-to-all expert-parallel path (shard_map over the "model" axis)
# ---------------------------------------------------------------------------

def _pad_experts(E: int, ep: int) -> int:
    return -(-E // ep) * ep


def _a2a_local(xt, w, idx, wg, wu, wo, *, cfg: ModelConfig, ep_axis: str,
               ep_size: int, capacity: int):
    """Per-device body under shard_map.

    xt:  (T_loc, D) local tokens            [sharded over "data"]
    idx: (T_loc, k) global expert ids       [local]
    wg/wu/wo: (E_loc, D, F) local expert weights [sharded over "model"]
    """
    T, D = xt.shape
    k = idx.shape[1]
    E_loc = wg.shape[0]
    cap = capacity

    # --- pack: per (destination device, local expert, capacity slot) ----
    flat_e = idx.reshape(-1)                     # (T*k,) global expert id
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    pos, keep = capacity_positions(flat_e, cap)
    # flat buffer layout: (ep_size * E_loc * cap); dest device major
    slot = flat_e * cap + pos                    # == dest*(E_loc*cap) + ...
    buf = token_dispatch(xt, flat_tok, slot, keep, ep_size * E_loc * cap,
                         use_kernel=cfg.use_pallas)
    buf = buf.reshape(ep_size, E_loc * cap, D)

    # --- all_to_all: send token buffers to expert owners ----------------
    recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)       # (ep_size, E_loc*cap, D)
    recv = recv.reshape(ep_size, E_loc, cap, D).transpose(1, 0, 2, 3)
    recv = recv.reshape(E_loc, ep_size * cap, D)

    # --- expert compute (batched MXU einsum) ----------------------------
    if cfg.use_pallas:
        from repro.kernels.moe_gemm import ops as moe_ops
        y = moe_ops.grouped_ffn(recv, wg, wu, wo, act=cfg.act)
    else:
        y = _expert_ffn(cfg, wg, wu, wo, recv)   # (E_loc, ep*cap, D)

    # --- reverse all_to_all ---------------------------------------------
    y = y.reshape(E_loc, ep_size, cap, D).transpose(1, 0, 2, 3)
    y = y.reshape(ep_size, E_loc * cap, D)
    back = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)       # (ep_size, E_loc*cap, D)

    # --- unpack + weighted combine ---------------------------------------
    out = token_combine(back.reshape(ep_size * E_loc * cap, D), flat_tok,
                        slot, keep, w.reshape(-1), T,
                        use_kernel=cfg.use_pallas)
    return out.astype(xt.dtype)


def moe_a2a(p, cfg: ModelConfig, x, mesh, *, data_axes=("data",),
            ep_axis: str = "model"):
    """x: (B, S, D) with batch sharded over `data_axes`."""
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E = cfg.n_experts
    ep_size = mesh.shape[ep_axis]
    E_pad = _pad_experts(E, ep_size)
    E_loc = E_pad // ep_size

    xt = x.reshape(-1, D)
    w, idx, aux = route(p, cfg, xt)

    # static per-device capacity: tokens_per_device * k * cf / E_pad
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    if (B * S) % n_data != 0:
        # tiny decode batches (e.g. long_500k, B*S=1) replicate tokens;
        # the a2a round-trip still lands every token on its expert owner.
        data_axes, n_data = (), 1
    t_loc = max((B * S) // n_data, 1)
    cap = max(int(math.ceil(t_loc * cfg.top_k * cfg.capacity_factor / E_pad)), 4)
    # MXU-align the capacity buffer
    cap = -(-cap // 8) * 8

    wg, wu, wo = p["wi_gate"], p["wi_up"], p["wo"]
    if E_pad != E:
        padn = E_pad - E
        wg = jnp.pad(wg, ((0, padn), (0, 0), (0, 0)))
        wu = jnp.pad(wu, ((0, padn), (0, 0), (0, 0)))
        wo = jnp.pad(wo, ((0, padn), (0, 0), (0, 0)))

    if not data_axes:
        dspec = P(None)
    elif len(data_axes) > 1:
        dspec = P(data_axes)
    else:
        dspec = P(data_axes[0])
    body = functools.partial(_a2a_local, cfg=cfg, ep_axis=ep_axis,
                             ep_size=ep_size, capacity=cap)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(dspec, dspec, dspec, P(ep_axis), P(ep_axis), P(ep_axis)),
        out_specs=dspec,
        check_rep=False,
    )(xt, w, idx, wg, wu, wo)

    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + layers.apply_mlp(p["shared"], cfg, x)
    return out, aux


def _replicated_ep_local(xt, w, idx, wg, wu, wo, *, cfg: ModelConfig,
                         axes, capacity: int):
    """Serving-layout expert parallelism: tokens REPLICATED on every
    device, experts sharded 1-per-device across ALL mesh axes, outputs
    combined with one small psum.  No weight collectives at all — the
    layout that makes 671B-class MoE decode ICI-cheap (EXPERIMENTS.md
    §Perf, iteration D2)."""
    T, D = xt.shape
    k = idx.shape[1]
    E_loc = wg.shape[0]
    cap = capacity
    dev = jax.lax.axis_index(axes)

    flat_e = idx.reshape(-1)
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    pos, fits = capacity_positions(flat_e, cap)
    local = (flat_e // E_loc) == dev
    keep = local & fits
    slot = jnp.where(local, flat_e % E_loc, 0) * cap + pos
    buf = token_dispatch(xt, flat_tok, slot, keep, E_loc * cap,
                         use_kernel=cfg.use_pallas)
    buf = buf.reshape(E_loc, cap, D)
    if cfg.use_pallas:
        from repro.kernels.moe_gemm import ops as moe_ops
        y = moe_ops.grouped_ffn(buf, wg, wu, wo, act=cfg.act)
    else:
        y = _expert_ffn(cfg, wg, wu, wo, buf)
    out = token_combine(y.reshape(E_loc * cap, D), flat_tok, slot, keep,
                        w.reshape(-1), T, use_kernel=cfg.use_pallas)
    return jax.lax.psum(out.astype(xt.dtype), axes)


def moe_replicated_ep(p, cfg: ModelConfig, x, mesh):
    """Decode-path MoE: see _replicated_ep_local."""
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E = cfg.n_experts
    n_dev = mesh.size
    axes = tuple(mesh.axis_names)
    E_pad = _pad_experts(E, n_dev)
    E_loc = E_pad // n_dev

    xt = x.reshape(-1, D)
    w, idx, aux = route(p, cfg, xt)
    T = xt.shape[0]
    cap = max(int(math.ceil(T * cfg.top_k * cfg.capacity_factor / E_pad)), 4)
    cap = min(-(-cap // 4) * 4, max(T, 4))

    wg, wu, wo = p["wi_gate"], p["wi_up"], p["wo"]
    if E_pad != E:
        padn = E_pad - E
        wg = jnp.pad(wg, ((0, padn), (0, 0), (0, 0)))
        wu = jnp.pad(wu, ((0, padn), (0, 0), (0, 0)))
        wo = jnp.pad(wo, ((0, padn), (0, 0), (0, 0)))

    body = functools.partial(_replicated_ep_local, cfg=cfg, axes=axes,
                             capacity=cap)
    espec = P(axes)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(None), P(None), P(None), espec, espec, espec),
        out_specs=P(None),
        check_rep=False,
    )(xt, w, idx, wg, wu, wo)
    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + layers.apply_mlp(p["shared"], cfg, x)
    return out, aux


def apply_moe(p, cfg: ModelConfig, x, mesh=None):
    impl = cfg.moe_impl
    if impl == "auto":
        impl = "a2a" if (mesh is not None and "model" in mesh.axis_names
                         and mesh.size > 1) else "dense"
    if impl == "replicated_ep":
        return moe_replicated_ep(p, cfg, x, mesh)
    if impl == "a2a":
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return moe_a2a(p, cfg, x, mesh, data_axes=data_axes)
    return moe_dense(p, cfg, x)
