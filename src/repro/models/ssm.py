"""Mamba-2 (SSD — state-space duality) block.  [arXiv:2405.21060]

Chunked SSD for train/prefill: within a chunk the computation is a
masked-attention-like quadratic form (MXU-friendly), across chunks a
recurrent state pass (B, H, P, N) carries the SSM state.  Decode is the
O(1)-per-token recurrence — this is what makes ``long_500k`` trivial for
SSM architectures.

The chunked scan also ships as a Pallas TPU kernel
(``repro.kernels.ssd_scan``) selected by ``cfg.use_pallas``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers


def init_ssm(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    d_inner = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = cfg.ssm_groups
    conv_dim = d_inner + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": layers.dense_init(
            ks[0], (D, 2 * d_inner + 2 * G * N + H), 0, dtype),
        "conv_w": layers.dense_init(ks[1], (cfg.ssm_conv, conv_dim), 0, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), dtype)},
        "out_proj": layers.dense_init(ks[2], (d_inner, D), 0, dtype),
    }


def _split_proj(cfg: ModelConfig, proj):
    d_inner = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner:d_inner + d_inner + 2 * G * N]
    dt = proj[..., -H:]
    return z, xBC, dt


def _causal_conv(cfg: ModelConfig, xBC, conv_w, conv_b, conv_cache=None):
    """Depthwise causal conv along S.  xBC: (B, S, C)."""
    K = cfg.ssm_conv
    if conv_cache is not None:
        xp = jnp.concatenate([conv_cache.astype(xBC.dtype), xBC], axis=1)
    else:
        xp = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(K))
    return jax.nn.silu(out + conv_b)


def _expand_groups(t, H):
    """(B, ..., G, N) -> (B, ..., H, N) by repeating each group."""
    G = t.shape[-2]
    rep = H // G
    return jnp.repeat(t, rep, axis=-2)


def ssd_chunked(xh, dt, A, Bh, Ch, *, chunk: int, init_state=None,
                unroll: bool = False, compute_dtype=jnp.float32):
    """Chunked SSD scan (jnp oracle / XLA path).

    xh: (B,S,H,P)  dt: (B,S,H)  A: (H,) negative  Bh/Ch: (B,S,H,N)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, Pd = xh.shape
    N = Bh.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nC = Sp // Q

    f32 = jnp.float32
    cd = jnp.dtype(compute_dtype)
    # chunk-major layout for the scan: (nC, B, Q, ...).  The matmul
    # operands may run in bf16 (Z3); decay/cumsum/state math stays f32.
    xh = xh.astype(cd).reshape(Bsz, nC, Q, H, Pd).transpose(1, 0, 2, 3, 4)
    dt = dt.astype(f32).reshape(Bsz, nC, Q, H).transpose(1, 0, 2, 3)
    Bh = Bh.astype(cd).reshape(Bsz, nC, Q, H, N).transpose(1, 0, 2, 3, 4)
    Ch = Ch.astype(cd).reshape(Bsz, nC, Q, H, N).transpose(1, 0, 2, 3, 4)

    causal = jnp.tril(jnp.ones((Q, Q), bool))
    h0 = (jnp.zeros((Bsz, H, Pd, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(h, inp):
        x_c, dt_c, B_c, C_c = inp                         # (B,Q,H,*) per chunk
        dA = dt_c * A[None, None, :]                      # (B,Q,H) <= 0
        cum = jnp.cumsum(dA, axis=1)
        # intra-chunk quadratic form: L[q,s] = exp(cum[q]-cum[s]), s <= q
        Lq = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Q,S,H)
        Lmat = jnp.where(causal[None, :, :, None], jnp.exp(Lq), 0.0)
        CB = jnp.einsum("bqhn,bshn->bqsh", C_c, B_c,
                        preferred_element_type=f32)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", (CB * Lmat).astype(cd),
                             (x_c.astype(f32) * dt_c[..., None]).astype(cd),
                             preferred_element_type=f32)
        # contribution of the carried state
        y_inter = jnp.einsum("bqhn,bhpn->bqhp",
                             C_c.astype(f32) * jnp.exp(cum)[..., None], h,
                             preferred_element_type=f32)
        # chunk summary -> new state
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)      # (B,Q,H)
        s_c = jnp.einsum("bsh,bshn,bshp->bhpn", decay_to_end * dt_c,
                         B_c.astype(f32), x_c.astype(f32),
                         preferred_element_type=f32)
        h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] + s_c
        return h_new, y_intra + y_inter

    h_final, ys = jax.lax.scan(step, h0, (xh, dt, Bh, Ch),
                               unroll=nC if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, Sp, H, Pd)[:, :S]
    return y, h_final


def ssm_forward(p, cfg: ModelConfig, x, *, conv_cache=None, init_state=None,
                return_cache: bool = False):
    """Full-sequence Mamba-2 block.  x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, proj)
    xBC_conv = _causal_conv(cfg, xBC, p["conv_w"], p["conv_b"], conv_cache)
    d_inner = cfg.d_inner
    G = cfg.ssm_groups
    xs = xBC_conv[..., :d_inner].reshape(B, S, H, Pd)
    Bs = xBC_conv[..., d_inner:d_inner + G * N].reshape(B, S, G, N)
    Cs = xBC_conv[..., d_inner + G * N:].reshape(B, S, G, N)
    Bs, Cs = _expand_groups(Bs, H), _expand_groups(Cs, H)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cfg.use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, h_final = ssd_ops.ssd(xs, dt, A, Bs, Cs, chunk=cfg.ssm_chunk,
                                 init_state=init_state)
    else:
        y, h_final = ssd_chunked(xs, dt, A, Bs, Cs, chunk=cfg.ssm_chunk,
                                 init_state=init_state,
                                 unroll=cfg.scan_unroll,
                                 compute_dtype=cfg.ssm_compute_dtype)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = layers.apply_norm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]
    if return_cache:
        K = cfg.ssm_conv
        if conv_cache is not None:
            # short continuation chunks: the carried tail still holds the
            # older inputs the next window needs
            tail = jnp.concatenate([conv_cache.astype(xBC.dtype), xBC],
                                   axis=1)[:, -(K - 1):]
        elif S >= K - 1:
            tail = xBC[:, -(K - 1):]
        else:
            tail = jnp.pad(xBC, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"state": h_final, "conv": tail}
    return out


def ssm_prefill_chunk(p, cfg: ModelConfig, x, cache, n_valid=None):
    """One chunked-prefill chunk through a Mamba-2 block: C tokens with
    recurrent state + conv-tail carry.  x: (B, C, D), cache as in
    ``ssm_decode``.  Returns (out (B, C, D), new_cache).

    ``n_valid`` (B,) masks bucket padding at the chunk tail: positions
    ``>= n_valid`` contribute NOTHING to the carried state (their
    softplus'd dt is zeroed, so the SSD decay is exp(0)=1 and the update
    term vanishes) and the carried conv tail is sliced to end at the
    last *valid* input — unlike attention, the recurrence integrates
    every token it sees, so pads must be frozen out explicitly.
    """
    B, C, _ = x.shape
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner, G, K = cfg.d_inner, cfg.ssm_groups, cfg.ssm_conv
    proj = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)
    xBC_conv = _causal_conv(cfg, xBC, p["conv_w"], p["conv_b"],
                            cache["conv"].astype(xBC.dtype))
    xs = xBC_conv[..., :d_inner].reshape(B, C, H, Pd)
    Bs = _expand_groups(
        xBC_conv[..., d_inner:d_inner + G * N].reshape(B, C, G, N), H)
    Cs = _expand_groups(
        xBC_conv[..., d_inner + G * N:].reshape(B, C, G, N), H)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if n_valid is not None:
        valid = jnp.arange(C)[None, :] < n_valid[:, None]       # (B, C)
        dt = jnp.where(valid[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"])
    if cfg.use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, h_final = ssd_ops.ssd(xs, dt, A, Bs, Cs, chunk=cfg.ssm_chunk,
                                 init_state=cache["state"])
    else:
        y, h_final = ssd_chunked(xs, dt, A, Bs, Cs, chunk=cfg.ssm_chunk,
                                 init_state=cache["state"],
                                 unroll=cfg.scan_unroll,
                                 compute_dtype=cfg.ssm_compute_dtype)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, C, d_inner).astype(x.dtype)
    y = layers.apply_norm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]
    # conv tail: the K-1 inputs preceding the valid frontier.  conv_in
    # row b covers chunk-relative positions [-(K-1), C); the tail ends at
    # n_valid, i.e. starts at conv_in index n_valid (clamped 0..C).
    if n_valid is None:
        tail = conv_in[:, -(K - 1):]
    else:
        start = jnp.clip(n_valid, 0, C)
        tail = jax.vmap(
            lambda row, s: jax.lax.dynamic_slice_in_dim(row, s, K - 1, 0)
        )(conv_in, start)
    return out, {"state": h_final, "conv": tail}


def ssm_decode(p, cfg: ModelConfig, x, cache):
    """Single-token recurrent step.  x: (B, 1, D)."""
    B = x.shape[0]
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner, G = cfg.d_inner, cfg.ssm_groups
    proj = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, proj)
    # conv over (cache ++ this step)
    conv_in = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)
    K = cfg.ssm_conv
    out_c = sum(conv_in[:, i + conv_in.shape[1] - K] * p["conv_w"][i]
                for i in range(K))
    xBC_conv = jax.nn.silu(out_c + p["conv_b"])[:, None]  # (B,1,C)
    xs = xBC_conv[..., :d_inner].reshape(B, H, Pd)
    Bs = _expand_groups(xBC_conv[..., d_inner:d_inner + G * N].reshape(B, G, N), H)
    Cs = _expand_groups(xBC_conv[..., d_inner + G * N:].reshape(B, G, N), H)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    h = cache["state"].astype(jnp.float32)                # (B,H,P,N)
    dec = jnp.exp(dt1 * A[None, :])                       # (B,H)
    h_new = (h * dec[:, :, None, None]
             + jnp.einsum("bh,bhn,bhp->bhpn", dt1, Bs.astype(jnp.float32),
                          xs.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", Cs.astype(jnp.float32), h_new)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = layers.apply_norm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]
    new_cache = {"state": h_new, "conv": conv_in[:, -(K - 1):]}
    return out, new_cache
