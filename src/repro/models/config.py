"""Unified model configuration for every architecture family in the zoo.

One frozen dataclass drives dense / MoE / SSM / hybrid / enc-dec / VLM
construction.  Every assigned architecture (see ``repro/configs/``) is a
pure-data instance of this class, so the same ``init`` / ``forward`` /
``decode`` machinery, sharding rules and dry-run harness work for all of
them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # ---- identity -------------------------------------------------------
    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    citation: str = ""

    # ---- core dims ------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 32000

    # ---- attention ------------------------------------------------------
    attn_type: str = "gqa"     # gqa | mla | none
    rope_theta: float = 10000.0
    sliding_window: int = 0    # 0 = full attention
    # attention pattern across layers; each scan step covers len(pattern)
    # layers.  ("full",) for uniform, ("local", "full") for gemma-2.
    attn_pattern: Tuple[str, ...] = ("full",)
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qk_norm: bool = False

    # ---- MLA (DeepSeek-V2/V3 multi-head latent attention) ---------------
    q_lora_rank: int = 0       # 0 -> full-rank q projection
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # ---- MoE ------------------------------------------------------------
    n_experts: int = 0         # routed experts (0 = dense FFN)
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0          # per-expert hidden (0 -> d_ff)
    first_dense_layers: int = 0  # leading layers use dense FFN (deepseek)
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # size expert-parallel buffers to the worst case (t_loc * top_k per
    # expert) so NO token is ever dropped.  Capacity drops are a
    # training-time throughput tradeoff (GShard semantics); the serving
    # engines force this on so sharded decode keeps single-device
    # semantics exactly, at the cost of larger dispatch buffers.
    moe_dropless: bool = False
    # expert-parallel implementation: "dense" (loop, small tests),
    # "a2a" (shard_map all-to-all, production) or "auto"
    moe_impl: str = "auto"
    # EP-A2A overlap (decode): split the decode step into two batch
    # halves whose MoE dispatch/FFN/combine stages are structurally
    # independent, so one half's lax.all_to_all overlaps the other
    # half's attention compute (Megatron-Core-style batch-level
    # overlap).  Contiguous-cache decode on a multi-device mesh only.
    overlap_a2a: bool = False

    # ---- multi-token prediction (DeepSeek-V3) ----------------------------
    n_mtp: int = 0
    # weight of the auxiliary MTP loss in the training objective
    mtp_loss_weight: float = 0.3

    # ---- SSM (Mamba-2 / SSD) ---------------------------------------------
    ssm_state: int = 0         # 0 = no ssm
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # bf16 SSD matmul operands (decay/cumsum/state stay f32) - Perf Z3
    ssm_compute_dtype: str = "float32"
    # recompute attention scores per kv-chunk in backward - Perf Z4
    remat_attn_chunks: bool = False

    # ---- hybrid (Zamba-2): shared attention block every k mamba blocks ---
    shared_attn_every: int = 0

    # ---- encoder-decoder (Whisper) ---------------------------------------
    n_enc_layers: int = 0

    # ---- modality frontend stubs ------------------------------------------
    frontend: str = ""         # "" | "audio" | "vision"
    frontend_tokens: int = 0   # e.g. 1500 audio frames, 256 image patches

    # ---- misc architecture -----------------------------------------------
    act: str = "silu"          # silu | gelu
    norm_type: str = "rmsnorm" # rmsnorm | layernorm
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale
    post_block_norm: bool = False  # gemma-2 post-attention/post-ffn norms
    mlp_gated: bool = True     # SwiGLU/GeGLU vs plain 2-layer MLP
    tie_embeddings: bool = True
    pos_embedding: str = "rope"  # rope | sinusoidal | none

    # ---- numerics / execution --------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots | full
    attn_chunk_q: int = 1024
    attn_chunk_k: int = 1024
    loss_chunk: int = 512      # sequence chunking for the CE loss
    # causal-aware chunk skipping in the attention loop (perf opt; see
    # EXPERIMENTS.md §Perf) — skips fully-masked (q-chunk, k-chunk) pairs.
    attn_skip_masked_chunks: bool = False
    use_pallas: bool = False   # Pallas kernels (TPU target / interpret tests)
    # Unroll every lax.scan (incl. chunk loops).  Used by the dry-run's
    # cost calibration: XLA's cost_analysis counts a while-loop body ONCE,
    # so scanned modules under-report FLOPs; the calibration lowers two
    # unrolled reduced-depth variants and extrapolates (launch/dryrun.py).
    scan_unroll: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm_block(self) -> bool:
        return self.ssm_state > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def layers_per_scan(self) -> int:
        return len(self.attn_pattern)

    @property
    def mla_qk_dim(self) -> int:
        return self.nope_head_dim + self.rope_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> "ModelConfig":
        if self.arch_type in ("dense", "moe", "vlm"):
            assert self.n_layers % self.layers_per_scan == 0, (
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"attn_pattern length {self.layers_per_scan}"
            )
        if self.is_moe:
            assert self.top_k > 0, f"{self.name}: MoE requires top_k > 0"
        if self.arch_type == "encdec":
            assert self.n_enc_layers > 0
        if self.arch_type == "hybrid":
            assert self.shared_attn_every > 0 and self.ssm_state > 0
        return self


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny CPU-runnable variant of the same architecture family.

    Used by the per-architecture smoke tests: 2 layers, d_model <= 512,
    <= 4 experts, same structural features (pattern, MLA, SSM, ...).
    """
    kw = dict(
        n_layers=2 * cfg.layers_per_scan if cfg.arch_type != "hybrid" else 4,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) or 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        dtype="float32",
        remat=False,
        attn_chunk_q=64,
        attn_chunk_k=64,
        loss_chunk=64,
        ssm_chunk=32,
    )
    if cfg.attn_type == "mla":
        kw.update(kv_lora_rank=32, rope_head_dim=16, nope_head_dim=32,
                  v_head_dim=32, q_lora_rank=(32 if cfg.q_lora_rank else 0))
    if cfg.is_moe:
        kw.update(n_experts=4, top_k=2, moe_d_ff=128,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.is_ssm_block:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_expand=2)
    if cfg.arch_type == "hybrid":
        kw.update(shared_attn_every=2)
    if cfg.arch_type == "encdec":
        kw.update(n_enc_layers=2)
    if cfg.frontend:
        kw.update(frontend_tokens=min(cfg.frontend_tokens, 16) or 16)
    if cfg.n_mtp:
        kw.update(n_mtp=1, mtp_loss_weight=cfg.mtp_loss_weight)
    if cfg.sliding_window:
        kw.update(sliding_window=64)
    kw.update(overrides)
    return cfg.replace(name=cfg.name + "-reduced", **kw).validate()
