"""Model assembly: init / loss / prefill / decode for every arch family.

Layer parameters are **stacked** along a leading "group" axis and the
layer stack executes under ``jax.lax.scan`` — the HLO stays compact no
matter how deep the model is (81-layer Zamba-2 and 61-layer DeepSeek-V3
compile in seconds on the 512-device placeholder mesh).

Layouts:
  dense/moe/vlm : blocks are groups of ``len(cfg.attn_pattern)`` sub-layers
                  (gemma-2 alternates local/global inside one group).
  moe w/ leading dense layers (DeepSeek): two stacks, scanned in sequence.
  ssm           : one stack of Mamba-2 blocks.
  hybrid        : (groups, period) nested stacks of Mamba-2 blocks with one
                  *shared* attention block applied at the top of each group
                  (Zamba-2's parameter-sharing trick) + a tail stack.
  encdec        : encoder stack + decoder stack with cross-attention.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models import layers, moe, quant, ssm


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _data_axes(mesh):
    if mesh is None:
        return None
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard_act(x, mesh, *, batch_dim: int = 0):
    """Constrain an activation's batch dim onto the data axes."""
    if mesh is None or mesh.size == 1:
        return x
    axes = _data_axes(mesh)
    n_data = 1
    for a in axes:
        n_data *= mesh.shape[a]
    if x.shape[batch_dim] % n_data != 0:
        return x  # tiny decode batches (long_500k B=1) stay replicated
    spec = [None] * x.ndim
    spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def _stacked_init(fn, key, n: int):
    """vmap an init function over a leading group axis."""
    return jax.vmap(fn)(jax.random.split(key, n))


def _window_for(cfg: ModelConfig, kind: str) -> int:
    return cfg.sliding_window if kind == "local" else 0


def _scan(cfg: ModelConfig, body, init, xs):
    n = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs,
                        unroll=n if cfg.scan_unroll else 1)


def _maybe_remat(cfg: ModelConfig, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# transformer block (dense / moe / vlm sub-layer)
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, *, use_moe: bool, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": layers.init_norm(cfg, cfg.d_model, dtype),
                         "ln2": layers.init_norm(cfg, cfg.d_model, dtype)}
    if cfg.attn_type == "mla":
        p["attn"] = layers.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = layers.init_attention(ks[0], cfg, dtype)
    if use_moe:
        p["moe"] = moe.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_block_norm:
        p["ln1_post"] = layers.init_norm(cfg, cfg.d_model, dtype)
        p["ln2_post"] = layers.init_norm(cfg, cfg.d_model, dtype)
    return p


def _block_full(p, cfg: ModelConfig, x, positions, *, kind: str, mesh,
                causal: bool = True):
    """Full-sequence sub-layer.  Returns (x, aux, cache_entry)."""
    window = _window_for(cfg, kind)
    h = layers.apply_norm(p["ln1"], x)
    if cfg.attn_type == "mla":
        attn_out, (ckv, kr) = layers.mla_full(p["attn"], cfg, h, positions)
        kv = {"ckv": ckv, "kr": kr}
    else:
        attn_out, (k, v) = layers.attention_full(p["attn"], cfg, h, positions,
                                                 window=window, causal=causal)
        kv = {"k": k, "v": v}
    if cfg.post_block_norm:
        attn_out = layers.apply_norm(p["ln1_post"], attn_out)
    x = x + attn_out
    h = layers.apply_norm(p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        ffn_out, aux = moe.apply_moe(p["moe"], cfg, h, mesh)
    else:
        ffn_out = layers.apply_mlp(p["mlp"], cfg, h)
    if cfg.post_block_norm:
        ffn_out = layers.apply_norm(p["ln2_post"], ffn_out)
    x = x + ffn_out
    x = shard_act(x, mesh)
    return x, aux, kv


def _block_decode(p, cfg: ModelConfig, x, pos, cache, *, kind: str, mesh,
                  block_tables=None, write_tables=None, live=None):
    """Decode / chunked-prefill sub-layer.  x: (B, C, D), pos: (B, C) —
    C=1 is the single-token decode step.  cache: dict of per-layer
    tensors (contiguous (B, S, ...) rows, or block pools when
    ``block_tables`` (B, nbt) is given; ``write_tables`` diverts chunked
    admission writes for already-pooled shared prefix blocks).
    ``live`` (B, C) bool masks dead serving rows (freed slots, bucket
    pads) out of MoE routing weights and expert-capacity accounting."""
    window = _window_for(cfg, kind)
    h = layers.apply_norm(p["ln1"], x)
    if cfg.attn_type == "mla":
        attn_out, new_cache = layers.mla_decode(p["attn"], cfg, h, pos, cache,
                                                mesh=mesh,
                                                block_table=block_tables,
                                                write_table=write_tables)
    else:
        attn_out, new_cache = layers.attention_decode(
            p["attn"], cfg, h, pos, cache, window=window,
            mesh=mesh, block_table=block_tables, write_table=write_tables)
    if cfg.post_block_norm:
        attn_out = layers.apply_norm(p["ln1_post"], attn_out)
    x = x + attn_out
    h = layers.apply_norm(p["ln2"], x)
    if "moe" in p:
        ffn_out, _ = moe.apply_moe(p["moe"], cfg, h, mesh, live=live)
    else:
        ffn_out = layers.apply_mlp(p["mlp"], cfg, h)
    if cfg.post_block_norm:
        ffn_out = layers.apply_norm(p["ln2_post"], ffn_out)
    # keep decode activations batch-sharded: without this the
    # replicated_ep MoE path leaves x replicated and every subsequent
    # attention layer runs the FULL batch on EVERY device (§Perf D3)
    x = shard_act(x + ffn_out, mesh)
    return x, new_cache


def _attn_cache_struct(cfg: ModelConfig, B: int, S: int, dtype, policy=None):
    """One attention layer's KV cache entry.

    Under a quantized ``CachePolicy`` each KV leaf is stored at the
    policy's dtype with a float32 ``<leaf>_scale`` sibling of the leaf's
    shape minus its trailing feature axis (one scale per written row /
    kv-head) — see ``repro.models.quant``.
    """
    pol = policy or quant.CachePolicy()
    sd = pol.storage_dtype(dtype)
    if cfg.attn_type == "mla":
        c = {"ckv": jnp.zeros((B, S, cfg.kv_lora_rank), sd),
             "kr": jnp.zeros((B, S, cfg.rope_head_dim), sd)}
    else:
        KH, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        c = {"k": jnp.zeros((B, S, KH, Dh), sd),
             "v": jnp.zeros((B, S, KH, Dh), sd)}
    if pol.quantized:
        for key in list(c):
            c[quant.scale_name(key)] = jnp.zeros(c[key].shape[:-1],
                                                 jnp.float32)
    return c


# ===========================================================================
# dense / moe / vlm family
# ===========================================================================

def _init_decoder_stacks(key, cfg: ModelConfig, dtype):
    lps = cfg.layers_per_scan
    p = {}
    kd, km = jax.random.split(key)
    n_dense_groups = cfg.first_dense_layers  # leading dense layers (deepseek)
    n_main = cfg.n_layers - n_dense_groups
    assert n_main % lps == 0
    n_groups = n_main // lps

    def group_init(k, use_moe):
        ks = jax.random.split(k, lps)
        return {f"sub{i}": _init_block(ks[i], cfg, use_moe=use_moe, dtype=dtype)
                for i in range(lps)}

    if n_dense_groups:
        p["dense_blocks"] = _stacked_init(
            lambda k: {"sub0": _init_block(k, cfg, use_moe=False, dtype=dtype)},
            kd, n_dense_groups)
    p["blocks"] = _stacked_init(
        functools.partial(group_init, use_moe=cfg.is_moe), km, n_groups)
    return p


def _run_stack(blocks, cfg: ModelConfig, x, positions, *, pattern, mesh,
               causal: bool, collect_cache: bool, collect_stages: bool = False):
    """scan over a stacked group of sub-layers (full-sequence)."""

    def group_fn(x, gp):
        aux = jnp.zeros((), jnp.float32)
        caches = {}
        for i, kind in enumerate(pattern):
            x, a, kv = _block_full(gp[f"sub{i}"], cfg, x, positions,
                                   kind=kind, mesh=mesh, causal=causal)
            aux = aux + a
            if collect_cache:
                caches[f"sub{i}"] = kv
        return x, (aux, caches if collect_cache else 0)

    group_fn = _maybe_remat(cfg, group_fn)

    def body(carry, gp):
        x, aux = carry
        x, (a, caches) = group_fn(x, gp)
        return (x, aux + a), (caches, x if collect_stages else 0)

    (x, aux), (caches, stages) = _scan(
        cfg, body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux, caches, stages


def _decode_stack(blocks, cfg: ModelConfig, x, pos, cache, *, pattern, mesh,
                  block_tables=None, write_tables=None, live=None):
    def body(x, inp):
        gp, gc = inp
        new_c = {}
        for i in range(len(pattern)):
            x, nc = _block_decode(gp[f"sub{i}"], cfg, x, pos, gc[f"sub{i}"],
                                  kind=pattern[i], mesh=mesh,
                                  block_tables=block_tables,
                                  write_tables=write_tables, live=live)
            new_c[f"sub{i}"] = nc
        return x, new_c

    x, new_cache = _scan(cfg, body, x, (blocks, cache))
    return x, new_cache


# ===========================================================================
# public API
# ===========================================================================

def init_params(key, cfg: ModelConfig):
    cfg.validate()
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": layers.embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": layers.init_norm(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), 0, dtype)

    at = cfg.arch_type
    if at in ("dense", "moe", "vlm"):
        p.update(_init_decoder_stacks(keys[2], cfg, dtype))
        if cfg.n_mtp:
            p["mtp"] = {
                "proj": layers.dense_init(keys[3], (2 * cfg.d_model, cfg.d_model),
                                          0, dtype),
                "block": _init_block(keys[4], cfg, use_moe=False, dtype=dtype),
                "norm": layers.init_norm(cfg, cfg.d_model, dtype),
            }
    elif at == "ssm":
        p["blocks"] = _stacked_init(
            lambda k: {"ln": layers.init_norm(cfg, cfg.d_model, dtype),
                       "mixer": ssm.init_ssm(k, cfg, dtype)},
            keys[2], cfg.n_layers)
    elif at == "hybrid":
        period = cfg.shared_attn_every
        n_groups, tail = divmod(cfg.n_layers, period)

        def mamba_block(k):
            return {"ln": layers.init_norm(cfg, cfg.d_model, dtype),
                    "mixer": ssm.init_ssm(k, cfg, dtype)}

        p["mamba_groups"] = jax.vmap(lambda k: _stacked_init(mamba_block, k, period))(
            jax.random.split(keys[2], n_groups))
        if tail:
            p["mamba_tail"] = _stacked_init(mamba_block, keys[3], tail)
        # ONE shared attention block reused at the top of every group
        p["shared_attn"] = _init_block(keys[4], cfg, use_moe=False, dtype=dtype)
    elif at == "encdec":
        def enc_block(k):
            return _init_block(k, cfg, use_moe=False, dtype=dtype)

        def dec_block(k):
            ks = jax.random.split(k, 2)
            b = _init_block(ks[0], cfg, use_moe=False, dtype=dtype)
            b["ln_x"] = layers.init_norm(cfg, cfg.d_model, dtype)
            b["xattn"] = layers.init_attention(ks[1], cfg, dtype)
            return b

        p["enc_blocks"] = _stacked_init(enc_block, keys[2], cfg.n_enc_layers)
        p["enc_norm"] = layers.init_norm(cfg, cfg.d_model, dtype)
        p["dec_blocks"] = _stacked_init(dec_block, keys[3], cfg.n_layers)
    else:
        raise ValueError(f"unknown arch_type {at}")
    return p


def _embed(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.array(cfg.d_model, jnp.float32)).astype(x.dtype)
    return x


def _head(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = layers._softcap(logits, cfg.final_logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# backbone (full sequence)
# ---------------------------------------------------------------------------

def backbone(params, cfg: ModelConfig, batch: Dict[str, Any], *, mesh=None,
             collect_cache: bool = False, collect_stages: bool = False):
    """Full-sequence forward.  Returns (hidden, aux_loss, caches, stages).

    ``stages`` (when requested): (n_stages, B, S, D) per-group hidden
    states — the representation stages consumed by the VAA distiller.
    """
    at = cfg.arch_type
    caches: Dict[str, Any] = {}
    stages = None

    if at in ("dense", "moe"):
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.arange(S)[None].repeat(B, 0)
        x = shard_act(_embed(params, cfg, tokens), mesh)
        aux = jnp.zeros((), jnp.float32)
        if "dense_blocks" in params:
            dense_cfg = cfg  # same attention; dense FFN chosen by params
            x, a, c, _ = _run_stack(params["dense_blocks"], dense_cfg, x,
                                    positions, pattern=("full",), mesh=mesh,
                                    causal=True, collect_cache=collect_cache)
            aux += a
            caches["dense_blocks"] = c
        x, a, c, stages = _run_stack(params["blocks"], cfg, x, positions,
                                     pattern=cfg.attn_pattern, mesh=mesh,
                                     causal=True, collect_cache=collect_cache,
                                     collect_stages=collect_stages)
        aux += a
        caches["blocks"] = c
        h = layers.apply_norm(params["final_norm"], x)
        return h, aux, caches, stages

    if at == "vlm":
        tokens = batch["tokens"]
        patches = batch["patches"]  # (B, P, D) precomputed (stub frontend)
        B, S_txt = tokens.shape
        x_txt = _embed(params, cfg, tokens)
        x = jnp.concatenate([patches.astype(x_txt.dtype), x_txt], axis=1)
        S = x.shape[1]
        positions = jnp.arange(S)[None].repeat(B, 0)
        x = shard_act(x, mesh)
        x, aux, c, stages = _run_stack(params["blocks"], cfg, x, positions,
                                       pattern=cfg.attn_pattern, mesh=mesh,
                                       causal=True, collect_cache=collect_cache,
                                       collect_stages=collect_stages)
        caches["blocks"] = c
        h = layers.apply_norm(params["final_norm"], x)
        return h, aux, caches, stages  # caller slices off patch positions

    if at == "ssm":
        tokens = batch["tokens"]
        x = shard_act(_embed(params, cfg, tokens), mesh)

        def body(x, inp):
            bp = inp
            blk = _maybe_remat(cfg, lambda xx: xx + (
                ssm.ssm_forward(bp["mixer"], cfg,
                                layers.apply_norm(bp["ln"], xx))))
            x = blk(x)
            x = shard_act(x, mesh)
            return x, (x if collect_stages else 0)

        if collect_cache:
            def body_c(x, bp):
                out, c = ssm.ssm_forward(bp["mixer"], cfg,
                                         layers.apply_norm(bp["ln"], x),
                                         return_cache=True)
                x = shard_act(x + out, mesh)
                return x, (c, x if collect_stages else 0)
            x, (c, stages) = _scan(cfg, body_c, x, params["blocks"])
            caches["blocks"] = c
        else:
            x, stages = _scan(cfg, body, x, params["blocks"])
        h = layers.apply_norm(params["final_norm"], x)
        if not collect_stages:
            stages = None
        return h, jnp.zeros((), jnp.float32), caches, stages

    if at == "hybrid":
        return _hybrid_backbone(params, cfg, batch, mesh=mesh,
                                collect_cache=collect_cache,
                                collect_stages=collect_stages)

    if at == "encdec":
        return _encdec_backbone(params, cfg, batch, mesh=mesh,
                                collect_cache=collect_cache,
                                collect_stages=collect_stages)

    raise ValueError(at)


def _hybrid_backbone(params, cfg: ModelConfig, batch, *, mesh, collect_cache,
                     collect_stages: bool = False):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)[None].repeat(B, 0)
    x = shard_act(_embed(params, cfg, tokens), mesh)
    caches: Dict[str, Any] = {"attn": [], "mamba": None, "tail": None}
    shared = params["shared_attn"]

    def mamba_scan(x, stack, collect):
        if collect:
            def body(x, bp):
                out, c = ssm.ssm_forward(bp["mixer"], cfg,
                                         layers.apply_norm(bp["ln"], x),
                                         return_cache=True)
                return x + out, c
            return _scan(cfg, body, x, stack)
        def body(x, bp):
            # nested remat: the outer group checkpoint recomputes this
            # forward during backward; the inner per-block checkpoint then
            # bounds the live set to ONE block's intermediates (§Perf Z2)
            fn = _maybe_remat(cfg, lambda xx: xx + ssm.ssm_forward(
                bp["mixer"], cfg, layers.apply_norm(bp["ln"], xx)))
            return fn(x), 0
        return _scan(cfg, body, x, stack)

    n_groups = jax.tree.leaves(params["mamba_groups"])[0].shape[0]

    # GROUP-level remat: one residual checkpoint per (shared-attn + period
    # mamba blocks) group — 13 saved boundaries instead of 78+attn for
    # zamba2-7b; see EXPERIMENTS.md §Perf iteration Z1.
    def group_fn(x, gp):
        x, a, kv = _block_full(shared, cfg, x, positions, kind="full",
                               mesh=mesh, causal=True)
        x, mc = mamba_scan(x, gp, collect_cache)
        return x, (kv if collect_cache else 0, mc)

    if not collect_cache:
        group_fn = _maybe_remat(cfg, group_fn)

    def outer_body(x, gp):
        x, (kv, mc) = group_fn(x, gp)
        return x, (kv, mc, x if collect_stages else 0)

    x, (kvs, mcs, stages) = _scan(cfg, outer_body, x, params["mamba_groups"])
    if collect_cache:
        caches["attn"] = kvs
        caches["mamba"] = mcs
    if "mamba_tail" in params:
        x, a, kv = _block_full(shared, cfg, x, positions, kind="full",
                               mesh=mesh, causal=True)
        x, tc = mamba_scan(x, params["mamba_tail"], collect_cache)
        if collect_cache:
            caches["tail_attn"] = kv
            caches["tail"] = tc
    h = layers.apply_norm(params["final_norm"], x)
    if not collect_stages:
        stages = None
    return h, jnp.zeros((), jnp.float32), caches, stages


def _encdec_backbone(params, cfg: ModelConfig, batch, *, mesh, collect_cache,
                     collect_stages: bool = False):
    frames = batch["frames"]          # (B, T_a, D) stub audio embeddings
    tokens = batch["tokens"]
    B, S = tokens.shape
    Ta = frames.shape[1]
    # --- encoder (bidirectional) ---
    enc_pos = jnp.arange(Ta)[None].repeat(B, 0)
    xe = frames.astype(_dtype(cfg))
    if cfg.pos_embedding == "sinusoidal":
        xe = xe + layers.sinusoidal_positions(enc_pos, cfg.d_model).astype(xe.dtype)
    xe = shard_act(xe, mesh)

    def enc_body(x, bp):
        fn = _maybe_remat(cfg, lambda xx: _block_full(
            bp, cfg, xx, enc_pos, kind="full", mesh=mesh, causal=False)[0])
        return fn(x), 0

    xe, _ = _scan(cfg, enc_body, xe, params["enc_blocks"])
    memory = layers.apply_norm(params["enc_norm"], xe)

    # --- decoder ---
    dec_pos = jnp.arange(S)[None].repeat(B, 0)
    x = _embed(params, cfg, tokens)
    if cfg.pos_embedding == "sinusoidal":
        x = x + layers.sinusoidal_positions(dec_pos, cfg.d_model).astype(x.dtype)
    x = shard_act(x, mesh)

    def dec_body(x, bp):
        def fn(xx):
            h = layers.apply_norm(bp["ln1"], xx)
            a, kv = layers.attention_full(bp["attn"], cfg, h, dec_pos,
                                          window=0, causal=True)
            xx = xx + a
            # cross attention
            h = layers.apply_norm(bp["ln_x"], xx)
            q, _, _ = layers.attention_qkv(bp["xattn"], cfg, h, dec_pos)
            _, mk, mv = layers.attention_qkv(bp["xattn"], cfg, memory, enc_pos)
            xa = layers.chunked_attention(
                q, mk, mv, dec_pos, enc_pos, causal=False,
                q_chunk=cfg.attn_chunk_q, k_chunk=cfg.attn_chunk_k,
                unroll=cfg.scan_unroll)
            xx = xx + xa.reshape(B, S, -1) @ bp["xattn"]["wo"]
            h = layers.apply_norm(bp["ln2"], xx)
            xx = xx + layers.apply_mlp(bp["mlp"], cfg, h)
            # dict layout matches init_decode_cache so prefill_into_cache
            # can graft the decoder self-KV (kv is attention_full's tuple)
            return xx, {"self": {"k": kv[0], "v": kv[1]},
                        "cross": {"k": mk, "v": mv}}
        if cfg.remat:
            fn = jax.checkpoint(fn)
        xx, c = fn(x)
        return xx, (c if collect_cache else 0, xx if collect_stages else 0)

    x, (dec_caches, stages) = _scan(cfg, dec_body, x, params["dec_blocks"])
    h = layers.apply_norm(params["final_norm"], x)
    caches = {}
    if collect_cache:
        caches = {"self": dec_caches["self"], "cross": dec_caches["cross"],
                  "memory": memory}
    if not collect_stages:
        stages = None
    return h, jnp.zeros((), jnp.float32), caches, stages


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def chunked_ce(params, cfg: ModelConfig, h, labels, mask):
    """Sequence-chunked CE: never materialises (B, S, V) logits at once.

    Returns (sum_nll, sum_tokens, sum_correct) as f32 scalars.
    """
    B, S, D = h.shape
    C = min(cfg.loss_chunk, S)
    pad = (-S) % C
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = h.shape[1] // C
    hc = h.reshape(B, n, C, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, C).transpose(1, 0, 2)
    mc = mask.reshape(B, n, C).transpose(1, 0, 2)

    def body(carry, inp):
        nll_s, tok_s, cor_s = carry
        hh, ll, mm = inp
        if cfg.use_pallas:
            from repro.kernels.kd_loss import ops as kd_ops
            w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
            nll, correct = kd_ops.ce_from_hidden(hh, w, ll,
                                                 softcap=cfg.final_logit_softcap)
        else:
            logits = _head(params, cfg, hh)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
            nll = lse - gold
            correct = (jnp.argmax(logits, -1) == ll).astype(jnp.float32)
        mmf = mm.astype(jnp.float32)
        return (nll_s + jnp.sum(nll * mmf), tok_s + jnp.sum(mmf),
                cor_s + jnp.sum(correct * mmf)), 0

    body = _maybe_remat(cfg, body) if cfg.remat else body
    (nll, tok, cor), _ = _scan(
        cfg, body, (jnp.zeros((), jnp.float32),) * 3, (hc, lc, mc))
    return nll, tok, cor


def loss_fn(params, cfg: ModelConfig, batch, *, mesh=None):
    """Autoregressive LM loss (Eq. 2).  Returns (loss, metrics)."""
    h, aux, _, _ = backbone(params, cfg, batch, mesh=mesh)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    if cfg.arch_type == "vlm":  # drop patch positions
        h = h[:, -labels.shape[1]:]
    nll, tok, cor = chunked_ce(params, cfg, h, labels, mask)
    loss = nll / jnp.maximum(tok, 1.0)
    metrics = {"nll": nll, "tokens": tok, "accuracy": cor / jnp.maximum(tok, 1.0),
               "aux_loss": aux, "ce_loss": loss}
    if cfg.n_mtp and "mtp" in params:
        mtp_loss = _mtp_loss(params, cfg, h, batch)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + cfg.mtp_loss_weight * mtp_loss
    return loss + aux, metrics


def _mtp_loss(params, cfg: ModelConfig, h, batch):
    """DeepSeek-V3 multi-token prediction head (depth 1): predict t+2."""
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    mp = params["mtp"]
    # combine hidden at t with embedding of token t+1
    emb_next = _embed(params, cfg, jnp.roll(tokens, -1, axis=1))
    hin = jnp.concatenate([layers.apply_norm(mp["norm"], h),
                           emb_next.astype(h.dtype)], axis=-1) @ mp["proj"]
    positions = jnp.arange(S)[None].repeat(B, 0)
    hout, _, _ = _block_full(mp["block"], cfg, hin, positions, kind="full",
                             mesh=None)
    labels2 = jnp.roll(labels, -1, axis=1)
    mask = jnp.ones_like(labels2, jnp.float32).at[:, -2:].set(0.0)
    nll, tok, _ = chunked_ce(params, cfg, hout, labels2, mask)
    return nll / jnp.maximum(tok, 1.0)


def mtp_chain_loss(params, cfg: ModelConfig, batch, *, depth: int,
                   mesh=None):
    """Teacher-forced CHAINED MTP loss: supervise the draft head at every
    chain depth ``1..depth``, feeding its own output hidden back in —
    exactly how ``_mtp_draft`` chains at inference.  ``_mtp_loss`` only
    trains depth 1 from backbone hiddens, so a head trained with it
    alone degrades sharply past the first speculative draft; train with
    this when serving with ``speculate > 1``.  Tokens are teacher-forced
    (ground truth at every depth) — on sequences the drafter gets right
    this matches the on-policy inference distribution.

    Depth j at position i combines the depth j-1 hidden with the
    embedding of token i+j and predicts token i+j+1; the last j+1
    positions roll around and are masked out.  Returns the mean NLL
    averaged over depths (depth 1 reproduces ``_mtp_loss`` exactly).
    """
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    mp = params["mtp"]
    h, _, _, _ = backbone(params, cfg, batch, mesh=mesh)
    positions = jnp.arange(S)[None].repeat(B, 0)
    total = jnp.zeros((), jnp.float32)
    for j in range(1, depth + 1):
        emb = _embed(params, cfg, jnp.roll(tokens, -j, axis=1))
        hin = jnp.concatenate([layers.apply_norm(mp["norm"], h),
                               emb.astype(h.dtype)], axis=-1) @ mp["proj"]
        h, _, _ = _block_full(mp["block"], cfg, hin, positions, kind="full",
                              mesh=mesh)
        lab = jnp.roll(labels, -j, axis=1)
        mask = jnp.ones_like(lab, jnp.float32).at[:, -(j + 1):].set(0.0)
        nll, tok, _ = chunked_ce(params, cfg, h, lab, mask)
        total = total + nll / jnp.maximum(tok, 1.0)
    return total / depth


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, *, mesh=None,
            return_hidden=False):
    """Runs the full prompt, returns (last_token_logits, cache).

    ``return_hidden`` packs the last position's pre-head hidden next to
    the logits — ``((logits, h_last), cache)`` — so a speculative
    engine can seed its first draft chain hot instead of burning the
    admission step's drafts on a zero hidden.
    """
    h, _, caches, _ = backbone(params, cfg, batch, mesh=mesh,
                               collect_cache=True)
    logits = _head(params, cfg, h[:, -1:])[:, 0]
    if return_hidden:
        return (logits, h[:, -1]), caches
    return logits, caches


def _place_tree(tree, mesh, spec_tree):
    """Lay a freshly-built cache tree out over ``mesh`` per the rules'
    PartitionSpecs.  ``mesh=None`` (or a trivial 1-device mesh) is a
    no-op, so single-device layouts stay bit-identical."""
    if mesh is None or mesh.size == 1:
        return tree
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, spec_tree)


def init_decode_cache(cfg: ModelConfig, B: int, S: int, mesh=None,
                      policy=None):
    """Zeroed cache pytree for ``decode_step`` (capacity S).

    ``policy`` (a ``quant.CachePolicy``) names the storage dtype of the
    SELF-attention KV leaves; quantized policies add per-row float32
    ``_scale`` siblings.  Recurrent state (ssm/hybrid), encdec cross KV
    and encoder memory opt out — they are read linearly every step, so
    quantizing them buys little and costs accuracy.  ``policy=None``
    keeps the historical param-dtype layout bit-for-bit.

    With ``mesh`` the cache is laid out with ``NamedSharding`` per
    ``sharding.rules.cache_specs`` — slot (batch) axes over the data
    axes, sequence over "model" where divisible — instead of living on
    one device.  ``mesh=None`` / 1-device meshes are unchanged.
    """
    dtype = _dtype(cfg)
    at = cfg.arch_type
    if mesh is not None and mesh.size > 1:
        from repro.sharding import rules
        tree = init_decode_cache(cfg, B, S, policy=policy)
        specs = rules.cache_specs(tree, mesh, batch=B, seq=S)
        return _place_tree(tree, mesh, specs)

    def attn_entry():
        return _attn_cache_struct(cfg, B, S, dtype, policy)

    def stack(entry, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), entry)

    if at in ("dense", "moe", "vlm"):
        lps = cfg.layers_per_scan
        n_groups = (cfg.n_layers - cfg.first_dense_layers) // lps
        c = {"blocks": stack({f"sub{i}": attn_entry() for i in range(lps)},
                             n_groups)}
        if cfg.first_dense_layers:
            c["dense_blocks"] = stack({"sub0": attn_entry()},
                                      cfg.first_dense_layers)
        return c
    if at == "ssm":
        entry = {"state": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                                     cfg.ssm_state), jnp.float32),
                 "conv": jnp.zeros((B, cfg.ssm_conv - 1,
                                    cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
                                   dtype)}
        return {"blocks": stack(entry, cfg.n_layers)}
    if at == "hybrid":
        period = cfg.shared_attn_every
        n_groups, tail = divmod(cfg.n_layers, period)
        entry = {"state": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                                     cfg.ssm_state), jnp.float32),
                 "conv": jnp.zeros((B, cfg.ssm_conv - 1,
                                    cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
                                   dtype)}
        c = {"mamba": stack(stack(entry, period), n_groups),
             "attn": stack(attn_entry(), n_groups + (1 if tail else 0))}
        if tail:
            c["tail"] = stack(entry, tail)
        return c
    if at == "encdec":
        self_entry = stack(attn_entry(), cfg.n_layers)
        cross = stack(_attn_cache_struct(cfg, B, cfg.frontend_tokens, dtype),
                      cfg.n_layers)
        return {"self": self_entry, "cross": cross,
                "memory": jnp.zeros((B, cfg.frontend_tokens, cfg.d_model), dtype)}
    raise ValueError(at)


def decode_offset(cfg: ModelConfig) -> int:
    """Leading cache positions occupied by the modality frontend.

    VLM prompts are ``[patches | text]``: the prefill cache stores patch
    rows first, so text decode positions start at ``frontend_tokens``.
    Every other family decodes from position ``prompt_len`` directly
    (the encdec frontend lives in the separate cross/memory entries).
    """
    return cfg.frontend_tokens if cfg.arch_type == "vlm" else 0


def decode_capacity(cfg: ModelConfig, prompt_len: int, max_new: int) -> int:
    """Exact decode-cache capacity for a prompt + ``max_new`` generated
    tokens (the first of which is sampled from the prefill logits)."""
    return decode_offset(cfg) + prompt_len + max_new


def decode_pos0(cfg: ModelConfig, prompt_len: int) -> int:
    """First decode position after a ``prompt_len``-token prefill."""
    return decode_offset(cfg) + prompt_len


def graft_cache_entry(dst, src):
    """Copy a prefill cache entry into a (same-or-larger) decode entry.

    Exactly one dim (the sequence axis) may differ between the decode
    and prefill entries; anything else is a caller bug and raises.
    """
    if dst.shape == src.shape:
        return src.astype(dst.dtype)
    diff = [ax for ax, (a, b) in enumerate(zip(dst.shape, src.shape))
            if a != b]
    if dst.ndim != src.ndim or len(diff) != 1:
        raise ValueError(
            f"graft_cache_entry: decode cache {dst.shape} and prefill cache "
            f"{src.shape} differ in more than one dim — the caches were "
            f"built for different batch/model shapes")
    ax = diff[0]
    if src.shape[ax] > dst.shape[ax]:
        raise ValueError(
            f"graft_cache_entry: prefill length {src.shape[ax]} exceeds "
            f"decode cache capacity {dst.shape[ax]} (axis {ax})")
    idx = [slice(None)] * dst.ndim
    idx[ax] = slice(0, src.shape[ax])
    return dst.at[tuple(idx)].set(src.astype(dst.dtype))


def prefill_into_cache(cfg: ModelConfig, decode_cache, prefill_cache):
    """Align a ``prefill`` cache into a ``decode_step`` cache.

    The ONE place that knows the cache layout per arch family:

      dense/moe : graft ``blocks`` (+ leading ``dense_blocks``) along the
                  sequence axis of each stacked KV / MLA-latent entry.
      vlm       : same — the prefill entries already contain the patch
                  rows, so the graft lands on ``[0, frontend_tokens + P)``
                  and decode positions continue at ``decode_pos0``.
      ssm       : recurrent state/conv tails are position-free; adopt.
      hybrid    : adopt mamba state; graft the per-group shared-attn KV;
                  fold the separately-stored ``tail_attn`` entry into the
                  last row of the stacked ``attn`` cache.
      encdec    : graft decoder ``self`` KV; adopt the fixed-length
                  ``cross`` KV and encoder ``memory``.
    """
    at = cfg.arch_type
    if at in ("dense", "moe", "vlm"):
        out = {"blocks": jax.tree.map(graft_cache_entry,
                                      decode_cache["blocks"],
                                      prefill_cache["blocks"])}
        if "dense_blocks" in decode_cache:
            out["dense_blocks"] = jax.tree.map(graft_cache_entry,
                                               decode_cache["dense_blocks"],
                                               prefill_cache["dense_blocks"])
        return out
    if at == "ssm":
        return jax.tree.map(graft_cache_entry, decode_cache, prefill_cache)
    if at == "hybrid":
        pc = {k: v for k, v in prefill_cache.items() if v is not None}
        out = {"mamba": jax.tree.map(graft_cache_entry,
                                     decode_cache["mamba"], pc["mamba"])}
        has_tail = "tail" in decode_cache
        if has_tail:
            n_groups = jax.tree.leaves(pc["attn"])[0].shape[0]

            def fold(dst, src, tail):
                body = graft_cache_entry(dst[:n_groups], src)
                return dst.at[:n_groups].set(body).at[-1].set(
                    graft_cache_entry(dst[-1], tail))

            out["attn"] = jax.tree.map(fold, decode_cache["attn"],
                                       pc["attn"], pc["tail_attn"])
            out["tail"] = jax.tree.map(graft_cache_entry,
                                       decode_cache["tail"], pc["tail"])
        else:
            out["attn"] = jax.tree.map(graft_cache_entry,
                                       decode_cache["attn"], pc["attn"])
        return out
    if at == "encdec":
        return {"self": jax.tree.map(graft_cache_entry,
                                     decode_cache["self"],
                                     prefill_cache["self"]),
                "cross": jax.tree.map(graft_cache_entry,
                                      decode_cache["cross"],
                                      prefill_cache["cross"]),
                "memory": graft_cache_entry(decode_cache["memory"],
                                            prefill_cache["memory"])}
    raise ValueError(at)


def decode_cache_batch_axes(cfg: ModelConfig, policy=None):
    """Tree of the batch-axis index of every decode-cache leaf.

    The batch axis sits behind a varying number of stacked layer axes
    (e.g. hybrid mamba state is (groups, period, B, ...)); discover it by
    diffing two abstract caches that differ only in B.  ``policy`` must
    match the cache being indexed — quantized policies add ``_scale``
    leaves, and the axes tree must mirror that structure.
    """
    a = jax.eval_shape(lambda: init_decode_cache(cfg, 2, 8, policy=policy))
    b = jax.eval_shape(lambda: init_decode_cache(cfg, 3, 8, policy=policy))

    def axis(x, y):
        return next(i for i, (p, q) in enumerate(zip(x.shape, y.shape))
                    if p != q)

    return jax.tree.map(axis, a, b)


# ---------------------------------------------------------------------------
# serving: block-paged decode cache
# ---------------------------------------------------------------------------

def decode_cache_seq_axes(cfg: ModelConfig, policy=None):
    """Tree of the sequence-axis index of every decode-cache leaf, or -1
    for leaves with no growing sequence axis (ssm state/conv, encdec
    cross KV and encoder memory).  Discovered by diffing two abstract
    caches that differ only in S — the -1 leaves are exactly the ones
    that stay slot-resident under the paged layout."""
    a = jax.eval_shape(lambda: init_decode_cache(cfg, 2, 8, policy=policy))
    b = jax.eval_shape(lambda: init_decode_cache(cfg, 2, 16, policy=policy))

    def axis(x, y):
        diff = [i for i, (p, q) in enumerate(zip(x.shape, y.shape)) if p != q]
        return diff[0] if diff else -1

    return jax.tree.map(axis, a, b)


def has_paged_leaves(cfg: ModelConfig) -> bool:
    """False only for families whose whole decode state is per-slot
    recurrent (pure ssm) — the paged engine then degenerates to the
    contiguous one with no block pool to manage."""
    return any(ax >= 0 for ax in jax.tree.leaves(decode_cache_seq_axes(cfg)))


def init_paged_cache(cfg: ModelConfig, n_slots: int, n_blocks: int,
                     block_len: int, mesh=None, policy=None):
    """Block-paged decode cache.

    Sequence-carrying leaves become per-leaf block pools: the contiguous
    (stacked_layers..., B, S, ...) leaf turns into (stacked_layers...,
    n_blocks, block_len, ...) — block id b is row b of EVERY pool, so one
    allocator id spans all layers (vLLM-style).  Leaves with no sequence
    axis (ssm/hybrid recurrent state, encdec cross KV + memory) keep
    their per-slot batch axis of ``n_slots``.  Block 0 is the trash
    block: never allocated, it absorbs the masked writes of finished
    slots (see ``repro.serve.paged``).

    With ``mesh`` the layout follows ``sharding.rules.paged_cache_specs``:
    each device owns a contiguous shard of every block pool (the
    allocator's per-shard free lists mirror this split) and pool feature
    dims shard over "model"; slot-resident leaves shard their slot axis
    over the data axes.  ``mesh=None`` / 1-device meshes are unchanged.
    """
    pool = init_decode_cache(cfg, n_blocks, block_len, policy=policy)
    slotted = init_decode_cache(cfg, n_slots, block_len, policy=policy)
    seq = decode_cache_seq_axes(cfg, policy=policy)
    tree = jax.tree.map(lambda p, s, ax: p if ax >= 0 else s,
                        pool, slotted, seq)
    if mesh is not None and mesh.size > 1:
        from repro.sharding import rules
        specs = rules.paged_cache_specs(
            tree, mesh,
            batch_axes=decode_cache_batch_axes(cfg, policy=policy),
            seq_axes=seq)
        return _place_tree(tree, mesh, specs)
    return tree


def match_cache_policy(template, sub):
    """Re-structure a full-precision cache ``sub`` to the (possibly
    quantized) ``template``'s policy: data leaves with a ``_scale``
    sibling in the template are quantized along their trailing feature
    axis (write-time scales); everything else passes through.  A
    no-op (identity structure) for unquantized templates."""
    pol = quant.policy_of(template)
    if not pol.quantized:
        return sub

    def walk(tmpl, src):
        if not isinstance(tmpl, dict):
            return src
        out = {}
        for key, tval in tmpl.items():
            if isinstance(key, str) and quant.is_scale_key(key):
                continue
            if isinstance(tval, dict):
                out[key] = walk(tval, src[key])
            elif isinstance(key, str) and quant.scale_name(key) in tmpl:
                q, s = quant.quantize(src[key], pol.kv_dtype)
                out[key] = q
                out[quant.scale_name(key)] = s
            else:
                out[key] = src[key]
        return out

    return walk(template, sub)


def scatter_prefill_paged(cfg: ModelConfig, paged_cache, sub, slot, ids,
                          mask, *, block_len: int):
    """Scatter a B=1 contiguous decode cache ``sub`` (already grafted via
    ``prefill_into_cache``, S = len(ids) * block_len) into the paged
    cache: paged leaves land in pool blocks ``ids`` (n_prompt_blocks,),
    slot-resident leaves in batch row ``slot``.  ``mask`` (same shape as
    ``ids``) is False for blocks whose content is already pooled (prefix
    sharing) — their writes are diverted to the trash block 0 instead of
    re-writing (identical) shared content.

    ``sub`` is always the full-precision prefill graft; when the paged
    cache is quantized, KV leaves are quantized here (per-row scales
    computed at write time) so pool content is a pure function of the
    written tokens — the invariant prefix sharing relies on."""
    pol = quant.policy_of(paged_cache)
    bat = decode_cache_batch_axes(cfg, policy=pol)
    seq = decode_cache_seq_axes(cfg, policy=pol)
    sub = match_cache_policy(paged_cache, sub)
    ids_eff = jnp.where(mask, ids, 0)

    def put(dst, src, bax, sax):
        if sax < 0:
            idx = [slice(None)] * dst.ndim
            idx[bax] = slot
            return dst.at[tuple(idx)].set(
                jnp.take(src, 0, axis=bax).astype(dst.dtype))
        s = jnp.take(src, 0, axis=bax)  # drop B; seq axis now sits at bax
        s = s.reshape(s.shape[:bax] + (-1, block_len) + s.shape[bax + 1:])
        s = jnp.moveaxis(s, bax, 0)     # (n_prompt_blocks, L..., bl, T...)
        d = jnp.moveaxis(dst, bax, 0)   # (n_blocks, L..., bl, T...)
        d = d.at[ids_eff].set(s.astype(d.dtype))
        return jnp.moveaxis(d, 0, bax)

    return jax.tree.map(put, paged_cache, sub, bat, seq)


def cache_nbytes(cfg: ModelConfig, B: int, S: int, policy=None) -> int:
    """Bytes of a contiguous (B, S) decode cache (abstract, no alloc).

    Summed per leaf at each leaf's OWN itemsize — under a quantized
    policy the cache mixes int8/fp8 KV leaves with float32 scale (and
    opted-out recurrent) leaves, so a single-itemsize estimate would
    misprice every equal-bytes comparison."""
    tree = jax.eval_shape(lambda: init_decode_cache(cfg, B, S,
                                                    policy=policy))
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def paged_cache_nbytes(cfg: ModelConfig, n_slots: int, n_blocks: int,
                       block_len: int, policy=None) -> int:
    """Bytes of the paged cache: block pools + slot-resident leaves,
    summed per leaf at each leaf's own itemsize (see cache_nbytes)."""
    tree = jax.eval_shape(
        lambda: init_paged_cache(cfg, n_slots, n_blocks, block_len,
                                 policy=policy))
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def _overlap_ok(cfg: ModelConfig, mesh, B: int, block_tables) -> bool:
    """Gate for the EP-A2A overlapped decode step.

    Contiguous-cache MoE decode on a multi-device "model" axis only, and
    the batch must split into two equal halves.  Paged caches are
    excluded: both halves would scatter into the SAME trash block row,
    and merging the two written pools is not expressible as a concat.
    """
    if not (cfg.overlap_a2a and cfg.is_moe and block_tables is None):
        return False
    if cfg.moe_impl not in ("auto", "a2a"):
        return False
    if mesh is None or "model" not in mesh.axis_names:
        return False
    return mesh.shape["model"] > 1 and B >= 2 and B % 2 == 0


def _decode_step_overlapped(params, cfg: ModelConfig, cache, x, pos, *,
                            mesh, live):
    """Batch-level EP-A2A overlap (Megatron-Core style): run the decode
    body on two independent batch halves, each with its own cache slice.
    The halves share no data flow, so XLA's latency-hiding scheduler can
    run half 0's MoE ``all_to_all`` concurrently with half 1's attention
    compute (asserted at the HLO level by
    ``launch.hlo_analysis.assert_a2a_overlap``).

    Expert capacity is computed per half (over B/2 rows), so this is NOT
    bitwise-identical to the unsplit step when drops occur; at serving
    batch sizes the per-half capacity ceil is the same and outputs match
    (the sharded identity tests exercise exactly this).
    """
    B = x.shape[0]
    half = B // 2
    bat = decode_cache_batch_axes(cfg, policy=quant.policy_of(cache))

    def run(lo, hi):
        c = jax.tree.map(
            lambda leaf, ax: jax.lax.slice_in_dim(leaf, lo, hi, axis=ax),
            cache, bat)
        lv = None if live is None else live[lo:hi]
        return _chunk_hidden(params, cfg, c, x[lo:hi], pos[lo:hi],
                             mesh=mesh, live=lv)

    h0, nc0 = run(0, half)
    h1, nc1 = run(half, B)
    h = jnp.concatenate([h0, h1], axis=0)
    new_cache = jax.tree.map(
        lambda a, b, ax: jnp.concatenate([a, b], axis=ax), nc0, nc1, bat)
    return h, new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *, mesh=None,
                block_tables=None, live=None):
    """One serving step: tokens (B, 1) at positions pos (B,).

    With ``block_tables`` (B, nbt) the cache is the paged layout of
    ``init_paged_cache``: sequence-carrying leaves are block pools read
    through the table; slot-resident leaves (ssm state, encdec
    cross/memory) are indexed by batch row exactly as before.

    ``live`` (B,) bool marks rows holding real requests; freed engine
    slots are masked out of MoE routing and expert-capacity accounting
    (``live=None`` treats every row as live — bit-identical to the
    pre-mask behavior).

    Returns (logits (B, V), new_cache).  This is the C=1 case of the
    shared ``_chunk_hidden`` body that chunked prefill feeds C-token
    chunks through.
    """
    x = _embed(params, cfg, tokens)
    lv = None if live is None else live[:, None]
    if _overlap_ok(cfg, mesh, x.shape[0], block_tables):
        h, new_cache = _decode_step_overlapped(params, cfg, cache, x,
                                               pos[:, None], mesh=mesh,
                                               live=lv)
    else:
        h, new_cache = _chunk_hidden(params, cfg, cache, x, pos[:, None],
                                     mesh=mesh, block_tables=block_tables,
                                     live=lv)
    return _head(params, cfg, h)[:, 0], new_cache


def _chunk_hidden(params, cfg: ModelConfig, cache, x, pos, *, mesh=None,
                  block_tables=None, write_tables=None, n_valid=None,
                  live=None):
    """Shared decode / chunked-prefill body: pre-embedded inputs x
    (B, C, D) at positions pos (B, C), written into (and attended
    against) the decode cache.  Returns (final-normed hidden (B, C, D),
    new_cache).

    C=1 is the classic decode step.  C>1 is one chunked-prefill chunk:
    attention families need no extra masking (per-query positional
    masks give in-chunk causality, and bucket-pad writes land beyond
    every live query's visibility), but the ssm/hybrid recurrence
    integrates everything it sees, so ``n_valid`` (B,) freezes state
    and conv-tail updates for pad positions (see ssm_prefill_chunk).

    ``live`` (B, C) bool masks dead rows/positions out of MoE routing
    and capacity; when omitted it is derived from ``n_valid`` (bucket
    pads past the real prompt are dead for routing purposes too).
    """
    at = cfg.arch_type
    C = x.shape[1]
    if live is None and n_valid is not None:
        live = jnp.arange(C)[None, :] < n_valid[:, None]

    if at in ("dense", "moe", "vlm"):
        if "dense_blocks" in params:
            x, c0 = _decode_stack(params["dense_blocks"], cfg, x, pos,
                                  cache["dense_blocks"], pattern=("full",),
                                  mesh=mesh, block_tables=block_tables,
                                  write_tables=write_tables, live=live)
        x, c1 = _decode_stack(params["blocks"], cfg, x, pos, cache["blocks"],
                              pattern=cfg.attn_pattern, mesh=mesh,
                              block_tables=block_tables,
                              write_tables=write_tables, live=live)
        new_cache = {"blocks": c1}
        if "dense_blocks" in params:
            new_cache["dense_blocks"] = c0
    elif at == "ssm":
        def body(x, inp):
            bp, bc = inp
            out, nc = _ssm_step(bp, cfg, x, bc, C, n_valid)
            return x + out, nc
        x, nc = _scan(cfg, body, x, (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nc}
    elif at == "hybrid":
        x, new_cache = _hybrid_decode(params, cfg, x, pos, cache, mesh=mesh,
                                      block_tables=block_tables,
                                      write_tables=write_tables,
                                      n_valid=n_valid, live=live)
    elif at == "encdec":
        x, new_cache = _encdec_decode(params, cfg, x, pos, cache, mesh=mesh,
                                      block_tables=block_tables,
                                      write_tables=write_tables)
    else:
        raise ValueError(at)

    return layers.apply_norm(params["final_norm"], x), new_cache


def _ssm_step(bp, cfg: ModelConfig, x, bc, C: int, n_valid):
    """One Mamba-2 block: the O(1) recurrence for C=1, the SSD chunk
    path (state + conv carry, pad-frozen via ``n_valid``) for C>1."""
    h = layers.apply_norm(bp["ln"], x)
    if C == 1:
        return ssm.ssm_decode(bp["mixer"], cfg, h, bc)
    return ssm.ssm_prefill_chunk(bp["mixer"], cfg, h, bc, n_valid)


def _hybrid_decode(params, cfg: ModelConfig, x, pos, cache, *, mesh,
                   block_tables=None, write_tables=None, n_valid=None,
                   live=None):
    shared = params["shared_attn"]
    C = x.shape[1]

    def mamba_body(x, inp):
        bp, bc = inp
        out, nc = _ssm_step(bp, cfg, x, bc, C, n_valid)
        return x + out, nc

    def group_body(x, inp):
        gp, gc, ac = inp
        x, nac = _block_decode(shared, cfg, x, pos, ac, kind="full", mesh=mesh,
                               block_tables=block_tables,
                               write_tables=write_tables, live=live)
        x, ngc = _scan(cfg, mamba_body, x, (gp, gc))
        return x, (ngc, nac)

    n_groups = jax.tree.leaves(params["mamba_groups"])[0].shape[0]
    has_tail = "mamba_tail" in params
    attn_cache = cache["attn"]
    attn_groups = jax.tree.map(lambda t: t[:n_groups], attn_cache)
    x, (nmc, nac) = _scan(
        cfg, group_body, x, (params["mamba_groups"], cache["mamba"], attn_groups))
    new_cache = {"mamba": nmc}
    if has_tail:
        tail_attn = jax.tree.map(lambda t: t[n_groups], attn_cache)
        x, nta = _block_decode(shared, cfg, x, pos, tail_attn, kind="full",
                               mesh=mesh, block_tables=block_tables,
                               write_tables=write_tables, live=live)
        x, ntc = _scan(cfg, mamba_body, x, (params["mamba_tail"], cache["tail"]))
        new_cache["tail"] = ntc
        new_cache["attn"] = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b[None]], 0), nac, nta)
    else:
        new_cache["attn"] = nac
    return x, new_cache


def _encdec_decode(params, cfg: ModelConfig, x, pos, cache, *, mesh,
                   block_tables=None, write_tables=None):
    B, C = x.shape[:2]
    if cfg.pos_embedding == "sinusoidal":
        x = x + layers.sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)

    def body(x, inp):
        bp, sc, cc = inp
        h = layers.apply_norm(bp["ln1"], x)
        a, nsc = layers.attention_decode(bp["attn"], cfg, h, pos, sc,
                                         window=0,
                                         block_table=block_tables,
                                         write_table=write_tables)
        x = x + a
        h = layers.apply_norm(bp["ln_x"], x)
        q, _, _ = layers.attention_qkv(bp["xattn"], cfg, h, pos)
        Ta = cc["k"].shape[1]
        kpos = jnp.arange(Ta)[None].repeat(B, 0)
        xa = layers.decode_attention(q, cc["k"], cc["v"], pos, kpos,
                                     causal=False)
        x = x + xa.reshape(B, C, -1) @ bp["xattn"]["wo"]
        h = layers.apply_norm(bp["ln2"], x)
        x = x + layers.apply_mlp(bp["mlp"], cfg, h)
        return x, nsc

    x, nsc = _scan(cfg, body, x, (params["dec_blocks"], cache["self"],
                                  cache["cross"]))
    return x, {"self": nsc, "cross": cache["cross"], "memory": cache["memory"]}


# ---------------------------------------------------------------------------
# serving: chunked prefill through the decode cache
# ---------------------------------------------------------------------------

def _encdec_encode(params, cfg: ModelConfig, cache, frames, *, mesh):
    """Run the encoder and write ``cross`` KV + ``memory`` into the
    decode cache — the fixed-shape half of an encdec chunked prefill
    (frames are always ``frontend_tokens`` long, so this never forces a
    new executable).  Bit-identical to the ``_encdec_backbone`` path."""
    B, Ta = frames.shape[:2]
    enc_pos = jnp.arange(Ta)[None].repeat(B, 0)
    xe = frames.astype(_dtype(cfg))
    if cfg.pos_embedding == "sinusoidal":
        xe = xe + layers.sinusoidal_positions(enc_pos, cfg.d_model).astype(xe.dtype)
    xe = shard_act(xe, mesh)

    def enc_body(x, bp):
        return _block_full(bp, cfg, x, enc_pos, kind="full", mesh=mesh,
                           causal=False)[0], 0

    xe, _ = _scan(cfg, enc_body, xe, params["enc_blocks"])
    memory = layers.apply_norm(params["enc_norm"], xe)
    mk, mv = jax.vmap(
        lambda bp: layers.attention_qkv(bp["xattn"], cfg, memory, enc_pos)[1:]
    )(params["dec_blocks"])
    cache = dict(cache)
    cache["cross"] = {"k": mk.astype(cache["cross"]["k"].dtype),
                      "v": mv.astype(cache["cross"]["v"].dtype)}
    cache["memory"] = memory.astype(cache["memory"].dtype)
    return cache


def prefill_chunked(params, cfg: ModelConfig, cache, batch, prompt_len, *,
                    chunk_len: int, mesh=None, block_tables=None,
                    write_tables=None):
    """Prefill a prompt THROUGH the decode cache in fixed-size chunks.

    ``batch`` is a B-row prefill batch whose ``tokens`` are padded (any
    values) to a bucket length such that the full input sequence —
    ``decode_offset(cfg) + tokens.shape[1]`` — is a multiple of
    ``chunk_len``; ``prompt_len`` (scalar or (B,)) is the TRUE token
    count.  ``cache`` is a decode cache (contiguous, or the paged
    slot-view + pools with ``block_tables`` (B, nbt); the tables must be
    wide enough for every padded position — table gathers clamp, so an
    undersized table would alias its last block).  Each chunk runs the
    shared ``_chunk_hidden`` decode body, so prompt processing and
    decode are ONE code path and the executable depends only on
    (bucket, chunk_len), not the true prompt length.

    Pad positions continue sequentially past the prompt: their
    attention writes land beyond every live query's causal visibility
    (and decode overwrites each position before attending to it), their
    contiguous writes past the cache capacity are dropped by the
    scatter, their paged writes fall through table rows pointing at the
    trash block, and the ssm/hybrid recurrence is explicitly frozen for
    them (``n_valid``).  Recurrent (no-sequence-axis) leaves are zeroed
    first so a reused slot's stale state never leaks into the new
    request.

    Returns (logits of the last real token (B, V), cache).
    """
    at = cfg.arch_type
    tokens = batch["tokens"]
    B, T_pad = tokens.shape
    offset = decode_offset(cfg)
    S_total = offset + T_pad
    if S_total % chunk_len:
        raise ValueError(
            f"padded input length {S_total} (offset {offset} + tokens "
            f"{T_pad}) must be a multiple of chunk_len {chunk_len}")
    seq = decode_cache_seq_axes(cfg, policy=quant.policy_of(cache))
    cache = jax.tree.map(
        lambda leaf, ax: jnp.zeros_like(leaf) if ax < 0 else leaf, cache, seq)
    if at == "encdec":
        cache = _encdec_encode(params, cfg, cache, batch["frames"], mesh=mesh)

    x_full = _embed(params, cfg, tokens)
    if at == "vlm":
        x_full = jnp.concatenate(
            [batch["patches"].astype(x_full.dtype), x_full], axis=1)
    total_real = offset + jnp.broadcast_to(
        jnp.asarray(prompt_len, jnp.int32).reshape(-1), (B,))

    n_chunks = S_total // chunk_len
    D = x_full.shape[-1]
    xs = x_full.reshape(B, n_chunks, chunk_len, D).transpose(1, 0, 2, 3)
    pos_full = jnp.arange(S_total)[None].repeat(B, 0)
    ps = pos_full.reshape(B, n_chunks, chunk_len).transpose(1, 0, 2)

    def body(carry, inp):
        cache, h_last = carry
        x_c, pos_c = inp
        start = pos_c[:, 0]
        n_valid = jnp.clip(total_real - start, 0, chunk_len)
        h, cache = _chunk_hidden(params, cfg, cache, x_c, pos_c, mesh=mesh,
                                 block_tables=block_tables,
                                 write_tables=write_tables, n_valid=n_valid)
        off = total_real - 1 - start
        here = (off >= 0) & (off < chunk_len)
        h_sel = jnp.take_along_axis(
            h, jnp.clip(off, 0, chunk_len - 1)[:, None, None], axis=1)[:, 0]
        h_last = jnp.where(here[:, None], h_sel, h_last)
        return (cache, h_last), 0

    h0 = jnp.zeros((B, D), _dtype(cfg))
    (cache, h_last), _ = jax.lax.scan(body, (cache, h0), (xs, ps))
    return _head(params, cfg, h_last[:, None])[:, 0], cache


# ---------------------------------------------------------------------------
# serving: scanned generation
# ---------------------------------------------------------------------------

def greedy_sample(keys, logits):
    """Default sampler: per-slot argmax.  keys (B, 2) ignored."""
    del keys
    return jnp.argmax(logits, -1).astype(jnp.int32)


def greedy_verify(keys, logits, draft):
    """Verify twin of ``greedy_sample``: emit the argmax of the TARGET
    logits at a drafted position; the draft is accepted iff it matches,
    so the emitted stream is exactly the greedy stream."""
    del keys
    tgt = jnp.argmax(logits, -1).astype(jnp.int32)
    return tgt, tgt == draft


def _verify_for(sampler):
    v = getattr(sampler, "verify", None)
    if v is not None:
        return v
    if sampler is greedy_sample:
        return greedy_verify
    raise ValueError(
        "speculative decode needs a sampler with a verify() method "
        "(see repro.serve.sampling)")


def _mtp_draft(params, cfg: ModelConfig, h, tok, pos, *, mesh=None):
    """One inference-time MTP draft: combine the final-normed hidden
    ``h`` (B, D) of the position that emitted ``tok`` (B,) with the
    embedding of ``tok`` — the exact training-time ``_mtp_loss``
    combination — and run the depth-1 MTP block at a single position.

    Returns (draft logits (B, V), hidden for chaining the next draft).
    The draft head reuses the LM head WITHOUT ``final_norm``, matching
    how training feeds the block output straight into ``chunked_ce``.
    """
    mp = params["mtp"]
    emb = _embed(params, cfg, tok[:, None])
    hin = jnp.concatenate([layers.apply_norm(mp["norm"], h[:, None]),
                           emb.astype(h.dtype)], axis=-1) @ mp["proj"]
    hout, _, _ = _block_full(mp["block"], cfg, hin, pos[:, None], kind="full",
                             mesh=mesh)
    return _head(params, cfg, hout)[:, 0], hout[:, 0]


def _spec_zero_rejected(cfg: ModelConfig, cache, pos, a, *, k: int,
                        block_tables=None):
    """Scrub the KV written for rejected draft positions.

    The verify chunk writes all ``k+1`` positions before acceptance is
    known; per slot, positions ``pos + a .. pos + k`` hold rejected
    drafts (``a`` = accepted length; done rows pass a=0 so every write
    is scrubbed).  Contiguous caches zero them in place — bit-identical
    to the never-written state token-by-token decode leaves behind —
    with KEPT positions diverted out of bounds (scatters drop OOB).
    Paged caches zero through the block tables with kept positions
    diverted to the trash block row 0 (table gathers clamp, and table
    columns past the allocation already point at trash).
    """
    B = pos.shape[0]
    jj = jnp.arange(k + 1)
    rej = jj[None, :] >= a[:, None]                      # (B, k+1)
    tgt = pos[:, None] + jj[None, :]                     # (B, k+1)
    pol = quant.policy_of(cache)
    bat = decode_cache_batch_axes(cfg, policy=pol)
    seq = decode_cache_seq_axes(cfg, policy=pol)
    bidx = jnp.arange(B)[:, None]

    def zero_leaf(leaf, bax, sax):
        if sax < 0:
            return leaf
        sax2 = sax if sax > bax else sax + 1
        l = jnp.moveaxis(jnp.moveaxis(leaf, bax, 0), sax2, 1)
        if block_tables is None:
            p = jnp.where(rej, tgt, l.shape[1])          # kept -> OOB drop
            l = l.at[bidx, p].set(0)
        else:
            bl = l.shape[1]
            blk = block_tables[bidx, tgt // bl]
            blk = jnp.where(rej, blk, 0)                 # kept -> trash row
            l = l.at[blk, tgt % bl].set(0)
        return jnp.moveaxis(jnp.moveaxis(l, 1, sax2), 0, bax)

    return jax.tree.map(zero_leaf, cache, bat, seq)


def _scan_generate(params, cfg: ModelConfig, cache, tok, pos, rem, done,
                   keys, eos, *, steps, sampler, return_logits, mesh,
                   block_tables=None):
    """The scanned decode body shared by the contiguous and paged paths."""

    def body(carry, _):
        tok, pos, rem, done, keys, cache = carry
        live = ~done
        logits, cache = decode_step(params, cfg, cache, tok[:, None], pos,
                                    mesh=mesh, block_tables=block_tables,
                                    live=live)
        ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
        sampled = sampler(ks[:, 0], logits)
        rem2 = rem - live.astype(rem.dtype)
        done2 = done | (live & ((sampled == eos) | (rem2 <= 0)))
        tok2 = jnp.where(live, sampled, tok)
        # finished slots stop advancing: their (stale) writes pin to
        # one in-capacity position until the slot is re-admitted
        pos2 = jnp.where(live, pos + 1, pos)
        out = (sampled, live, logits) if return_logits else (sampled, live)
        return (tok2, pos2, rem2, done2, ks[:, 1], cache), out

    carry, ys = jax.lax.scan(body, (tok, pos, rem, done, keys, cache),
                             None, length=steps)
    tok, pos, rem, done, keys, cache = carry
    res = {"tokens": ys[0].T, "valid": ys[1].T, "next_tok": tok,
           "pos": pos, "remaining": rem, "done": done, "rng": keys,
           "cache": cache}
    if return_logits:
        res["logits"] = jnp.moveaxis(ys[2], 0, 1)
    return res


def _scan_generate_spec(params, cfg: ModelConfig, cache, tok, pos, rem, done,
                        keys, h, eos, *, steps, k, sampler, mesh,
                        block_tables=None):
    """Self-speculative scanned decode: each step drafts ``k`` tokens
    with the model's own MTP head, verifies all ``k+1`` positions in ONE
    C=(k+1) pass through the shared ``_chunk_hidden`` decode body, and
    advances each slot by its accepted length (>= 1 emission per live
    step, <= k+1).

    Greedy acceptance is an exact argmax-prefix match, so the emitted
    stream is bit-identical to token-by-token decode; stochastic
    samplers use residual rejection sampling (``sampler.verify``) whose
    emitted marginal equals the target distribution.  The carry gains
    ``h`` (B, D): the final-normed hidden of the position that emitted
    the pending token, seeding the next step's draft chain.  Rejected
    draft writes are scrubbed after acceptance so slot cache state
    matches token-by-token decode exactly.
    """
    verify = _verify_for(sampler)
    B = tok.shape[0]
    C = k + 1

    def body(carry, _):
        tok, pos, rem, done, keys, h, cache = carry
        live = ~done
        ks = jax.vmap(lambda kk: jax.random.split(kk, C + 1))(keys)

        # ---- draft: chain the depth-1 MTP head greedily, k times ----
        drafts = []
        dh, dt = h, tok
        for j in range(k):
            dlogits, dh = _mtp_draft(params, cfg, dh, dt,
                                     jnp.maximum(pos - 1 + j, 0), mesh=mesh)
            dt = jnp.argmax(dlogits, -1).astype(jnp.int32)
            drafts.append(dt)

        # ---- verify: one C=k+1 forward through the decode body ----
        chunk = jnp.stack([tok] + drafts, axis=1)         # (B, C)
        cpos = pos[:, None] + jnp.arange(C)[None, :]
        x = _embed(params, cfg, chunk)
        lv = jnp.broadcast_to(live[:, None], (B, C))
        hc, cache = _chunk_hidden(params, cfg, cache, x, cpos, mesh=mesh,
                                  block_tables=block_tables, live=lv)
        logits = _head(params, cfg, hc)                   # (B, C, V)

        # ---- accept: emission chain with in-chunk eos/budget stops ----
        # position j's logits verify draft j+1 (j < k) or sample the
        # bonus token (j = k); a rejection emits the verifier's token
        # and ends the chain, so every live step emits at least once.
        emit = live
        toks_out, valid_out = [], []
        a = jnp.zeros((B,), jnp.int32)
        new_tok, new_done = tok, done
        for j in range(C):
            if j < k:
                tj, acc = verify(ks[:, j], logits[:, j], drafts[j])
            else:
                tj = sampler(ks[:, j], logits[:, j])
                acc = jnp.zeros((B,), bool)
            valid = emit
            rem = rem - valid.astype(rem.dtype)
            stop = valid & ((tj == eos) | (rem <= 0))
            new_done = new_done | stop
            new_tok = jnp.where(valid, tj, new_tok)
            a = a + valid.astype(jnp.int32)
            toks_out.append(tj)
            valid_out.append(valid)
            emit = emit & acc & ~stop
        new_pos = jnp.where(live, pos + a, pos)
        idx = jnp.clip(a - 1, 0, k)
        new_h = jnp.take_along_axis(hc, idx[:, None, None], axis=1)[:, 0]
        new_h = jnp.where(live[:, None], new_h, h)
        # scrub everything past the accepted frontier.  Dead lanes
        # (a = 0) keep chunk position 0: the plain scan re-writes the
        # pending token's kv at the parked frontier every step, and
        # position 0 of the verify chunk is that exact write, so keeping
        # it preserves bit-identity of the whole cache
        cache = _spec_zero_rejected(cfg, cache, pos, jnp.maximum(a, 1), k=k,
                                    block_tables=block_tables)
        out = (jnp.stack(toks_out, 1), jnp.stack(valid_out, 1))
        return (new_tok, new_pos, rem, new_done, ks[:, C], new_h, cache), out

    carry, ys = jax.lax.scan(body, (tok, pos, rem, done, keys, h, cache),
                             None, length=steps)
    tok, pos, rem, done, keys, h, cache = carry
    return {"tokens": jnp.moveaxis(ys[0], 0, 1).reshape(B, steps * C),
            "valid": jnp.moveaxis(ys[1], 0, 1).reshape(B, steps * C),
            "next_tok": tok, "pos": pos, "remaining": rem, "done": done,
            "rng": keys, "h_spec": h, "cache": cache}


@functools.lru_cache(maxsize=32)
def _generate_spec_fn(cfg: ModelConfig, steps: int, k: int, sampler, mesh):
    """Compiled speculative scanned-decode body, cached per
    (cfg, steps, k, sampler, mesh).  The cache operand is donated."""

    def run(params, cache, tok, pos, rem, done, keys, h, eos):
        return _scan_generate_spec(params, cfg, cache, tok, pos, rem, done,
                                   keys, h, eos, steps=steps, k=k,
                                   sampler=sampler, mesh=mesh)

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=32)
def _generate_spec_paged_fn(cfg: ModelConfig, steps: int, k: int, sampler,
                            mesh):
    """Paged twin of ``_generate_spec_fn``: block tables threaded into
    every verify chunk (reads, writes, and the rejected-KV scrub)."""

    def run(params, cache, bt, tok, pos, rem, done, keys, h, eos):
        return _scan_generate_spec(params, cfg, cache, tok, pos, rem, done,
                                   keys, h, eos, steps=steps, k=k,
                                   sampler=sampler, mesh=mesh,
                                   block_tables=bt)

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=32)
def _generate_fn(cfg: ModelConfig, steps: int, sampler, return_logits: bool,
                 mesh):
    """Compiled scanned-decode body, cached per (cfg, steps, sampler).

    ``sampler`` must be hashable (module-level function or frozen
    dataclass instance, see repro/serve/sampling.py).  The cache operand
    is donated: one host dispatch runs ``steps`` decode steps.
    """

    def run(params, cache, tok, pos, rem, done, keys, eos):
        return _scan_generate(params, cfg, cache, tok, pos, rem, done, keys,
                              eos, steps=steps, sampler=sampler,
                              return_logits=return_logits, mesh=mesh)

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=32)
def _generate_paged_fn(cfg: ModelConfig, steps: int, sampler,
                       return_logits: bool, mesh):
    """Paged twin of ``_generate_fn``: same scan, plus the (read-only)
    per-slot block tables threaded into every ``decode_step``."""

    def run(params, cache, bt, tok, pos, rem, done, keys, eos):
        return _scan_generate(params, cfg, cache, tok, pos, rem, done, keys,
                              eos, steps=steps, sampler=sampler,
                              return_logits=return_logits, mesh=mesh,
                              block_tables=bt)

    return jax.jit(run, donate_argnums=(1,))


def generate(params, cfg: ModelConfig, cache, first_tok, pos0, *, steps: int,
             sampler=None, rng=None, eos_id=None, remaining=None, mesh=None,
             return_logits: bool = False, block_tables=None,
             speculate: int = 0, spec_h=None):
    """Run ``steps`` decode steps as ONE ``lax.scan`` dispatch.

    ``first_tok`` (B,) or (B, 1) is the token fed at ``pos0`` (B,) —
    normally the sampler applied to the prefill logits, so it is already
    emission #1 of the request; the scan emits ``steps`` more.  The
    decode cache is donated to the compiled scan.

    Per-slot engine state rides through the scan carry: ``remaining``
    (emissions still allowed; slots with 0 start done and only produce
    discarded garbage), ``eos_id`` stopping, and per-slot RNG ``rng``
    (B, 2) split once per step regardless of slot liveness, so a scan
    split into segments samples identically to one long scan.

    With ``block_tables`` (B, nbt) the cache is the block-paged layout of
    ``init_paged_cache`` and every decode step reads/writes through the
    tables; the tables themselves are fixed for the whole segment (the
    engine allocates a request's blocks at admission).

    With ``speculate=k`` (> 0) each scan step drafts ``k`` tokens via
    the MTP head and verifies ``k+1`` positions in one C=(k+1) chunk —
    per-slot advance becomes the accepted length, ``tokens``/``valid``
    widen to (B, steps * (k+1)), the result gains the carried ``h_spec``
    (pass it back as ``spec_h`` to continue a segmented decode;
    admission starts from zeros — a cold first draft just gets
    rejected), and the RNG stream differs from non-speculative decode
    (k+2 splits per step).  Requires an MTP head (``cfg.n_mtp`` with
    ``params["mtp"]`` — dense/moe/vlm families).

    Returns a dict with ``tokens``/``valid`` (B, steps), the carried
    ``next_tok``/``pos``/``remaining``/``done``/``rng``, the updated
    ``cache``, and (when ``return_logits``) the raw per-step ``logits``
    (B, steps, V) — bit-identical to a per-token ``decode_step`` loop.
    """
    if sampler is None:
        sampler = greedy_sample
    B = first_tok.shape[0]
    tok = jnp.asarray(first_tok).reshape(B).astype(jnp.int32)
    pos0 = jnp.asarray(pos0).reshape(B).astype(jnp.int32)
    if rng is None:
        rng = jax.random.split(jax.random.PRNGKey(0), B)
    if remaining is None:
        remaining = jnp.full((B,), steps, jnp.int32)
    remaining = jnp.asarray(remaining).reshape(B).astype(jnp.int32)
    eos = jnp.int32(-1 if eos_id is None else eos_id)
    if speculate:
        if return_logits:
            raise ValueError("return_logits is not supported with "
                             "speculative decode")
        if not (cfg.n_mtp and "mtp" in params):
            raise ValueError(
                "speculative decode needs an MTP head (cfg.n_mtp > 0 with "
                "params['mtp'] — dense/moe/vlm families only)")
        h = (jnp.zeros((B, cfg.d_model), _dtype(cfg)) if spec_h is None
             else jnp.asarray(spec_h, _dtype(cfg)).reshape(B, cfg.d_model))
        if block_tables is not None:
            fn = _generate_spec_paged_fn(cfg, int(steps), int(speculate),
                                         sampler, mesh)
            return fn(params, cache, jnp.asarray(block_tables, jnp.int32),
                      tok, pos0, remaining, remaining <= 0, rng, h, eos)
        fn = _generate_spec_fn(cfg, int(steps), int(speculate), sampler, mesh)
        return fn(params, cache, tok, pos0, remaining, remaining <= 0, rng,
                  h, eos)
    if block_tables is not None:
        fn = _generate_paged_fn(cfg, int(steps), sampler, bool(return_logits),
                                mesh)
        return fn(params, cache, jnp.asarray(block_tables, jnp.int32), tok,
                  pos0, remaining, remaining <= 0, rng, eos)
    fn = _generate_fn(cfg, int(steps), sampler, bool(return_logits), mesh)
    return fn(params, cache, tok, pos0, remaining, remaining <= 0, rng, eos)
