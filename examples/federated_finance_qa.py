"""Case study 2 analogue: DeepSeek-MoE for financial open-ended QA.

The paper's second case study distills TinyLlama / OLMo / BLOOM device
models into DeepSeek-MoE-16B.  This runs the same pipeline shape at CPU
scale: 3 device families -> deepseek-style MoE student (first dense
layer + shared experts), plus a comparison against the FedKMT
(logits-only) ablation on the SAME uploads.

  PYTHONPATH=src python examples/federated_finance_qa.py
"""
from repro.core.baselines import run_fedkmt
from repro.federated.simulation import SimulationConfig, run_deepfusion
from repro.federated.server import ServerConfig
from repro.models.config import ModelConfig

V = 256
small = dict(vocab_size=V, dtype="float32", remat=False,
             attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32)

tinyllama_t = ModelConfig(name="tinyllama-t", n_layers=3, d_model=96,
                          n_heads=4, n_kv_heads=2, head_dim=24, d_ff=192,
                          **small).validate()
olmo_t = ModelConfig(name="olmo-t", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=4, head_dim=16, d_ff=256, **small).validate()
bloom_t = ModelConfig(name="bloom-t", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, head_dim=16, d_ff=128,
                      norm_type="layernorm", act="gelu", mlp_gated=False,
                      pos_embedding="sinusoidal", **small).validate()

# deepseek-moe-style student: leading dense layer, 2 shared experts
moe_cfg = ModelConfig(name="deepseek-moe-tiny", arch_type="moe", n_layers=3,
                      d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                      d_ff=256, n_experts=4, top_k=2, moe_d_ff=128,
                      n_shared_experts=2, first_dense_layers=1,
                      **small).validate()

if __name__ == "__main__":
    sim = SimulationConfig(n_devices=8, n_domains=4, vocab=V, seq_len=48,
                           device_steps=30, device_batch=8, seed=1)
    server = ServerConfig(moe_cfg=moe_cfg, distill_steps=30, distill_batch=8,
                          tune_steps=30, tune_batch=8, seq_len=48,
                          n_stages=2, p_q=32, vaa_dim=64, seed=1)
    print("=== DeepFusion (VAA feature + logits distillation) ===")
    params, rep = run_deepfusion(sim, server, [tinyllama_t, olmo_t, bloom_t])
    print("\n=== FedKMT ablation (logits only) on the SAME uploads ===")
    _, rep_kmt = run_fedkmt(sim, server, [tinyllama_t, olmo_t, bloom_t],
                            uploads=rep["uploads"], corpus=rep["corpus"])
    a, b = rep["metrics"], rep_kmt["metrics"]
    print(f"\nDeepFusion log-ppl {a['log_ppl']:.4f}  "
          f"vs FedKMT {b['log_ppl']:.4f}  "
          f"(delta {b['log_ppl']-a['log_ppl']:+.4f}; positive = VAA wins)")
