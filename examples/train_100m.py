"""End-to-end driver: train a ~100M-param LLM for a few hundred steps.

Uses the real GPT-2 config (124M params, vocab 50257) from the registry,
the AdamW + cosine substrate, and the synthetic multi-domain corpus.
On the production mesh this is the same train_step the dry-run lowers.

  PYTHONPATH=src python examples/train_100m.py --steps 300 --batch 8

On one CPU core a 300-step run takes a while; pass --steps 30 for a
quick validation run.
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.data.federated import FederatedCorpus
from repro.models import model as M
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.checkpoint import save_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save", default="")
    args = ap.parse_args()

    cfg = get_config("gpt2")  # 124M params
    cfg = cfg.replace(dtype="float32", remat=False,
                      attn_chunk_q=128, attn_chunk_k=128, loss_chunk=128)
    corpus = FederatedCorpus.build(seed=0, n_devices=4, n_domains=4,
                                   vocab=cfg.vocab_size)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    sched = cosine_schedule(args.lr, args.steps, warmup=args.steps // 20)

    @jax.jit
    def step_fn(params, opt, batch, lr):
        (loss, metrics), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt, stats = adamw_update(g, opt, params, lr=lr,
                                          weight_decay=0.01)
        return params, opt, loss, metrics["accuracy"]

    t0 = time.time()
    for s in range(args.steps):
        batch = corpus.mixed_eval_batch(args.batch, args.seq, seed_salt=s)
        params, opt, loss, acc = step_fn(params, opt, batch, sched(s))
        if s % max(args.steps // 20, 1) == 0 or s == args.steps - 1:
            tok_s = (s + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {s:4d}  loss {float(loss):.4f}  "
                  f"acc {float(acc):.3f}  ({tok_s:.0f} tok/s)", flush=True)
    if args.save:
        save_pytree(params, args.save)
        print("saved to", args.save)


if __name__ == "__main__":
    main()
