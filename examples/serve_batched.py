"""Continuous-batching serving demo on reduced configs.

Thin client of ``repro.serve.ServeEngine``: submits mixed-length
requests for two very different families — an SSM (mamba2, O(1) state)
and a GQA dense model — and lets the slot-based engine keep the batch
full.  Cache grafting and the scanned decode live in the model layer
(``prefill_into_cache`` / ``generate``); this file only builds prompts.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-1.3b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import mixed_lengths
from repro.models import model as M
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seg-len", type=int, default=8)
    args = ap.parse_args()
    cfg = get_config(args.arch, variant="reduced").replace(vocab_size=512)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    lengths = mixed_lengths(args.requests, args.prompt_len, args.gen)
    max_len = max(M.decode_capacity(cfg, p, g) for p, g in lengths)
    engine = ServeEngine(params, cfg, n_slots=args.slots, max_len=max_len,
                         seg_len=args.seg_len)
    for p, g in lengths:
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, p)),
                             jnp.int32)
        engine.submit({"tokens": prompt}, max_new=g)
    t0 = time.time()
    comps = engine.run()
    dt = time.time() - t0
    n_tok = engine.stats["generated_tokens"]
    print(f"{args.arch}: {len(comps)} requests, {n_tok} tokens "
          f"at {n_tok / dt:.1f} tok/s")
    print("first sequence:", comps[min(comps)].tokens[:16])


if __name__ == "__main__":
    main()
