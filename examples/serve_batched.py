"""Batched serving demo: prefill + KV/SSM-cache decode on reduced configs.

Demonstrates the same prefill/decode_step API the dry-run lowers for the
production mesh, on CPU-sized variants of two very different families:
an SSM (mamba2 — O(1) state) and a GQA dense model.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-1.3b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M


def generate(cfg, params, prompts, gen_len):
    B, P = prompts.shape
    cap = P + gen_len + 1
    logits, pc = jax.jit(lambda p, b: M.prefill(p, cfg, b))(
        params, {"tokens": prompts})
    cache = M.init_decode_cache(cfg, B, cap)

    def graft(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        for ax, (a, b) in enumerate(zip(dst.shape, src.shape)):
            if a != b:
                idx = [slice(None)] * dst.ndim
                idx[ax] = slice(0, b)
                return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    if cfg.arch_type in ("dense", "moe"):
        cache["blocks"] = jax.tree.map(graft, cache["blocks"], pc["blocks"])
        if "dense_blocks" in pc:
            cache["dense_blocks"] = jax.tree.map(
                graft, cache["dense_blocks"], pc["dense_blocks"])
    elif cfg.arch_type == "ssm":
        cache = {"blocks": pc["blocks"]}
    step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(gen_len):
        pos = jnp.full((B,), P + i, jnp.int32)
        logits, cache = step(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    return jnp.concatenate(out, 1), B * gen_len / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    cfg = get_config(args.arch, variant="reduced").replace(vocab_size=512)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    gen, tps = generate(cfg, params, prompts, args.gen)
    print(f"{args.arch}: generated {gen.shape} at {tps:.1f} tok/s")
    print("first sequence:", np.asarray(gen[0])[:16])


if __name__ == "__main__":
    main()
