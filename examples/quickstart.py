"""Quickstart: the full DeepFusion pipeline in ~2 minutes on CPU.

6 heterogeneous edge devices (2 LLM families) x 4 knowledge domains
-> one-shot upload -> cluster -> VAA-distill -> merge -> tune -> eval.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.models.config import ModelConfig
from repro.federated.simulation import SimulationConfig, run_deepfusion
from repro.federated.server import ServerConfig

V = 256
small = dict(vocab_size=V, dtype="float32", remat=False,
             attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32)

# Two heterogeneous on-device LLM families (the paper's setting: each
# device picks an architecture matching its hardware).
gpt2_tiny = ModelConfig(name="gpt2-tiny", n_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=4, head_dim=16, d_ff=128,
                        norm_type="layernorm", act="gelu", mlp_gated=False,
                        pos_embedding="sinusoidal", **small).validate()
llama_tiny = ModelConfig(name="llama-tiny", n_layers=3, d_model=96,
                         n_heads=4, n_kv_heads=2, head_dim=24, d_ff=192,
                         **small).validate()

# The global MoE (a tiny qwen-moe-like config: 4 experts, top-2, 1 shared)
moe_cfg = ModelConfig(name="moe-tiny", arch_type="moe", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                      d_ff=128, n_experts=4, top_k=2, moe_d_ff=128,
                      n_shared_experts=1, **small).validate()

sim = SimulationConfig(n_devices=6, n_domains=4, vocab=V, seq_len=48,
                       device_steps=30, device_batch=8, seed=0)
server = ServerConfig(moe_cfg=moe_cfg, distill_steps=30, distill_batch=8,
                      tune_steps=30, tune_batch=8, seq_len=48,
                      n_stages=2, p_q=32, vaa_dim=64)

if __name__ == "__main__":
    params, report = run_deepfusion(sim, server, [gpt2_tiny, llama_tiny])
    m = report["metrics"]
    print("\n=== DeepFusion quickstart done ===")
    print(f"global MoE log-perplexity : {m['log_ppl']:.4f}")
    print(f"token accuracy            : {m['accuracy']:.3f}")
    print(f"one-shot comm cost        : {report['comm_bytes']/1e6:.2f} MB")
    print(f"trainable fraction (PhIII): {report['trainable_fraction']:.2%}")
